//! Operator executors: the runtime counterparts of
//! [`OpKind`](crate::graph::OpKind), fused into per-stage chains.

use crate::columnar::ColumnBatch;
use crate::graph::{FoldFn, ReduceFn, SinkKind, WindowAgg};
use crate::metrics::{Metrics, MetricsRegistry};
use crate::time::{TsFn, WatermarkGen, WatermarkState, WindowAssigner};
use crate::value::{Batch, BatchData, Fnv1a, Value};
use std::collections::{BTreeMap, HashMap};
use std::hash::BuildHasherDefault;
use std::sync::atomic::AtomicU64;
use std::sync::{Arc, Mutex};

/// Keyed-state maps hash short encoded keys with [`Fnv1a`] — SipHash's
/// per-call setup cost dominates at that size, and sharing `value`'s
/// hasher keeps one FNV implementation in the codebase. (An earlier
/// exec-local copy probed `state == 0` on every `write` to decide
/// whether to seed, silently re-seeding mid-stream whenever a write
/// boundary fell on a zero state; `Fnv1a` initializes explicitly.)
pub(crate) type FnvMap<V> = HashMap<Vec<u8>, V, BuildHasherDefault<Fnv1a>>;

/// Looks up keyed state without allocating on the hit path: the key is
/// encoded into a reusable scratch buffer and only cloned on first sight.
/// One hash probe on the hit path, two on a miss (probe + insert).
fn keyed_entry<'m, V>(
    map: &'m mut FnvMap<V>,
    scratch: &mut Vec<u8>,
    key: &Value,
    init: impl FnOnce(&Value) -> V,
) -> &'m mut V {
    scratch.clear();
    key.encode_into(scratch);
    // The safe single-probe form (`if let Some(v) = map.get_mut(..) {
    // return v; }` then insert) is rejected by today's borrow checker —
    // the failed probe's borrow is extended over the insert arm (NLL
    // problem case #3, accepted under Polonius) — so the hit reference
    // is carried over a raw pointer.
    if let Some(v) = map.get_mut(scratch.as_slice()) {
        let p: *mut V = v;
        // SAFETY: `p` points into `map`, which stays exclusively borrowed
        // for `'m`; the map is not touched again before the reference is
        // returned, and the returned lifetime is the map borrow's.
        return unsafe { &mut *p };
    }
    // miss: the entry probe is the second and last hash of the key
    map.entry(scratch.clone()).or_insert_with(|| init(key))
}

/// Input handed to one executor: the chain head receives the shared
/// [`Batch`] handle; chain-interior executors receive the previous
/// operator's recycled output buffer, drained in place.
pub enum ChainInput<'a> {
    /// A shared batch handle (chain head, flush tail, external callers).
    Shared(Batch),
    /// A recycled buffer being drained: the records move out, the
    /// allocation stays behind for the next batch.
    Recycled(&'a mut Vec<Value>),
}

impl<'a> ChainInput<'a> {
    /// Number of input records.
    pub fn len(&self) -> usize {
        match self {
            ChainInput::Shared(b) => b.len(),
            ChainInput::Recycled(v) => v.len(),
        }
    }

    /// True when there are no input records.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Consumes the input, yielding its records. A recycled buffer is
    /// drained (allocation retained); a shared batch is taken
    /// copy-on-write (see [`Batch::into_values`]).
    pub fn drain(self) -> ValueDrain<'a> {
        match self {
            ChainInput::Shared(b) => ValueDrain::Owned(b.into_values().into_iter()),
            ChainInput::Recycled(v) => ValueDrain::Recycled(v.drain(..)),
        }
    }
}

impl<'a> From<Batch> for ChainInput<'a> {
    fn from(b: Batch) -> Self {
        ChainInput::Shared(b)
    }
}

impl<'a> From<Vec<Value>> for ChainInput<'a> {
    fn from(v: Vec<Value>) -> Self {
        ChainInput::Shared(Batch::new(v))
    }
}

/// Record iterator produced by [`ChainInput::drain`].
pub enum ValueDrain<'a> {
    /// Records taken out of a shared batch.
    Owned(std::vec::IntoIter<Value>),
    /// Records drained from a recycled buffer.
    Recycled(std::vec::Drain<'a, Value>),
}

impl Iterator for ValueDrain<'_> {
    type Item = Value;
    fn next(&mut self) -> Option<Value> {
        match self {
            ValueDrain::Owned(i) => i.next(),
            ValueDrain::Recycled(d) => d.next(),
        }
    }
    fn size_hint(&self) -> (usize, Option<usize>) {
        match self {
            ValueDrain::Owned(i) => i.size_hint(),
            ValueDrain::Recycled(d) => d.size_hint(),
        }
    }
}

/// A runtime operator: consumes record batches, emits records; `flush`
/// runs at end-of-stream to drain any held state.
///
/// `process` consumes a [`ChainInput`]. At the chain head that is the
/// shared [`Batch`] handle, taken copy-on-write — a single-owner chain
/// mutates the allocation in place while a batch still shared with a
/// sibling `split` edge is copied privately. Inside a fused chain it is
/// the previous operator's recycled output buffer: records are drained in
/// place and **no `Vec` or `Arc` is allocated per operator** — the only
/// allocation on the steady-state chain path is the one `Batch`
/// constructed at the chain's edge (see [`run_chain`]). Executors that
/// only *count* (the non-collecting sinks) never materialise a copy of a
/// shared batch at all, which keeps pure fan-out pipelines fully
/// zero-copy end to end.
pub trait OpExec: Send {
    /// Processes one input batch, appending outputs to `out`.
    fn process(&mut self, input: ChainInput<'_>, out: &mut Vec<Value>);
    /// Like [`OpExec::process`], additionally appending one routing hash
    /// per emitted record to `hashes` (aligned with `out`). The keying
    /// operators override this to capture the key hash they already pay
    /// for when the pair is constructed — downstream hash shuffles then
    /// read the column instead of re-walking `Value` trees. Every other
    /// operator leaves `hashes` untouched and the chain edge skips the
    /// column.
    fn process_hashed(
        &mut self,
        input: ChainInput<'_>,
        out: &mut Vec<Value>,
        _hashes: &mut Vec<u64>,
    ) {
        self.process(input, out);
    }
    /// Drains state at end-of-stream.
    fn flush(&mut self, _out: &mut Vec<Value>) {}
    /// Serialises held state for a drain-and-handoff dynamic update,
    /// draining it from this (exiting) incarnation. The returned value is
    /// a `Value::List` of `Pair(key, state)` entries — the coordinator
    /// re-partitions entries by key hash across the replacement instances
    /// before handing them to [`OpExec::restore`]. `None` ⇒ stateless (or
    /// currently empty), nothing to hand off.
    fn snapshot(&mut self) -> Option<Value> {
        None
    }
    /// Restores state captured by [`OpExec::snapshot`] on a prior
    /// incarnation; `state` is the `Value::List` of entries assigned to
    /// this instance. Called before the first batch is processed.
    fn restore(&mut self, _state: Value) {}
    /// Processes one typed columnar batch, when this executor has a
    /// columnar fast path. The monomorphized executors in
    /// `runtime::col_exec` override this to iterate native column slices
    /// directly; the default hands the batch back untouched
    /// ([`ColumnFlow::Fallback`]) and [`run_chain_data`] materializes
    /// `Value` rows for the remainder of the chain — so a mixed chain is
    /// always correct, merely slower from the first row-only operator on.
    fn process_columns(&mut self, input: ColumnBatch) -> ColumnFlow {
        ColumnFlow::Fallback(input)
    }
    /// Advances the operator's event-time clock to `wm`, appending any
    /// panes that became complete to `out`, and returns the watermark to
    /// forward downstream. The default passes the watermark through
    /// untouched; a timestamp assigner returns `None` (it replaces the
    /// upstream time domain with its own, see
    /// [`OpExec::take_watermark`]).
    fn on_watermark(&mut self, wm: i64, out: &mut Vec<Value>) -> Option<i64> {
        let _ = out;
        Some(wm)
    }
    /// Polled after each processed batch: a watermark this operator
    /// *generated* from the records it just saw. The runtime cascades it
    /// through the remainder of the chain (firing event-time windows on
    /// the way) and forwards it to downstream stages. `None` ⇒ no
    /// advance since the last poll (the common case for everything but
    /// timestamp assigners).
    fn take_watermark(&mut self) -> Option<i64> {
        None
    }
}

/// What one executor produced from a columnar input batch (see
/// [`OpExec::process_columns`]).
pub enum ColumnFlow {
    /// The operator ran columnar and produced a columnar output; the
    /// chain stays on the fast path.
    Columns(ColumnBatch),
    /// The operator ran columnar but its output has no static layout
    /// (e.g. a window emitting aggregate rows); the remainder of the
    /// chain runs on `Value` rows.
    Rows(Vec<Value>),
    /// The operator has no columnar path (or the batch's layout was not
    /// the one it is compiled for); the *unconsumed* input is handed
    /// back and this operator plus the remainder of the chain run on
    /// materialized rows.
    Fallback(ColumnBatch),
}

/// Reusable scratch state threaded through [`run_chain`], one per stage
/// instance: a double-buffer pair whose allocations are recycled across
/// batches, plus the key-hash column the chain's final keying operator
/// fills. With these, a fused chain performs **zero per-operator `Vec` or
/// `Arc` allocations** in steady state — the only allocation per chain
/// invocation is the single `Batch` constructed at the chain's edge
/// (whose payload `Vec` departs downstream with it).
pub struct ChainBuffers {
    /// Most recent operator output (the chain edge takes it).
    a: Vec<Value>,
    /// Spare buffer swapped in as each interior operator's destination.
    b: Vec<Value>,
    /// Key-hash column aligned with the final output (see
    /// [`OpExec::process_hashed`]).
    hashes: Vec<u64>,
    metrics: Option<Metrics>,
}

impl ChainBuffers {
    /// Creates an empty buffer pair; pass the job metrics to account
    /// buffer reuse (`chain_buffer_reuses` / `chain_buffer_allocs`).
    pub fn new(metrics: Option<Metrics>) -> Self {
        ChainBuffers {
            a: Vec::new(),
            b: Vec::new(),
            hashes: Vec::new(),
            metrics,
        }
    }

    /// Accounts one destination-buffer use: a capacity increase means the
    /// buffer (re)allocated; an unchanged nonzero capacity is a reuse of
    /// the recycled allocation.
    fn note_dest(&self, cap_before: usize, cap_after: usize) {
        if let Some(m) = &self.metrics {
            if cap_after > cap_before {
                MetricsRegistry::add(&m.chain_buffer_allocs, 1);
            } else if cap_before > 0 {
                MetricsRegistry::add(&m.chain_buffer_reuses, 1);
            }
        }
    }

    /// Constructs the chain-edge batch from the final output buffer,
    /// attaching the key-hash column when the last operator produced one.
    /// The buffer's allocation departs inside the batch — the one
    /// allocation per chain invocation.
    fn take_batch(&mut self) -> Batch {
        if self.a.is_empty() {
            return Batch::empty();
        }
        let values = std::mem::take(&mut self.a);
        if self.hashes.len() == values.len() {
            Batch::with_hashes(values, std::mem::take(&mut self.hashes))
        } else {
            Batch::new(values)
        }
    }
}

/// Feeds `batch` through a fused chain of executors, double-buffering
/// intermediate results through `bufs` so no `Vec` or `Arc` is allocated
/// per operator: the shared input handle is consumed by the head, every
/// interior hand-off drains a recycled buffer, and one `Batch` is
/// constructed at the chain's edge. An empty chain passes the handle
/// through untouched (refcount move, no copy).
pub fn run_chain(ops: &mut [Box<dyn OpExec>], batch: Batch, bufs: &mut ChainBuffers) -> Batch {
    if ops.is_empty() || batch.is_empty() {
        return batch;
    }
    let (head, rest) = ops.split_first_mut().expect("chain is non-empty");
    bufs.hashes.clear();
    bufs.a.clear();
    let cap = bufs.a.capacity();
    if rest.is_empty() {
        head.process_hashed(ChainInput::Shared(batch), &mut bufs.a, &mut bufs.hashes);
    } else {
        head.process(ChainInput::Shared(batch), &mut bufs.a);
    }
    bufs.note_dest(cap, bufs.a.capacity());
    let n_rest = rest.len();
    for (j, op) in rest.iter_mut().enumerate() {
        if bufs.a.is_empty() {
            return Batch::empty();
        }
        bufs.b.clear();
        let cap = bufs.b.capacity();
        if j + 1 == n_rest {
            op.process_hashed(
                ChainInput::Recycled(&mut bufs.a),
                &mut bufs.b,
                &mut bufs.hashes,
            );
        } else {
            op.process(ChainInput::Recycled(&mut bufs.a), &mut bufs.b);
        }
        bufs.note_dest(cap, bufs.b.capacity());
        std::mem::swap(&mut bufs.a, &mut bufs.b);
    }
    bufs.take_batch()
}

/// [`run_chain`] over either data-plane representation. A row batch
/// takes the classic path unchanged. A columnar batch is fed through
/// each executor's [`OpExec::process_columns`] until the chain ends
/// (columns out), an operator emits layout-less rows (remainder runs on
/// rows), or an operator has no columnar path (the batch is
/// materialized and the remainder — including that operator — runs on
/// rows). Empty intermediate results short-circuit exactly like
/// [`run_chain`].
pub fn run_chain_data(
    ops: &mut [Box<dyn OpExec>],
    data: BatchData,
    bufs: &mut ChainBuffers,
) -> BatchData {
    let cb = match data {
        BatchData::Rows(b) => return BatchData::Rows(run_chain(ops, b, bufs)),
        BatchData::Columns(cb) => cb,
    };
    if ops.is_empty() || cb.is_empty() {
        return BatchData::Columns(cb);
    }
    let mut cur = cb;
    for i in 0..ops.len() {
        if cur.is_empty() {
            return BatchData::Rows(Batch::empty());
        }
        match ops[i].process_columns(cur) {
            ColumnFlow::Columns(next) => cur = next,
            ColumnFlow::Rows(rows) => {
                return BatchData::Rows(run_chain(&mut ops[i + 1..], Batch::new(rows), bufs));
            }
            ColumnFlow::Fallback(same) => {
                return BatchData::Rows(run_chain(&mut ops[i..], same.to_batch(), bufs));
            }
        }
    }
    BatchData::Columns(cur)
}

/// Flushes a fused chain: each operator's drained state flows through the
/// remainder of the chain.
pub fn flush_chain(ops: &mut [Box<dyn OpExec>]) -> Vec<Value> {
    let mut pending: Vec<Value> = Vec::new();
    for i in 0..ops.len() {
        let mut out = Vec::new();
        if !pending.is_empty() {
            ops[i].process(std::mem::take(&mut pending).into(), &mut out);
        }
        ops[i].flush(&mut out);
        pending = out;
    }
    pending
}

/// Advances a fused chain's event-time clock: starting at operator
/// `from`, each operator observes the watermark (firing any due panes),
/// and its fired panes flow through the *remainder* of the chain as
/// ordinary data before the next operator sees the watermark — so a
/// downstream aggregation absorbs a fired pane before its own clock
/// moves. Returns the watermark to forward out of the chain, `None` if
/// some operator swallowed it (e.g. a mid-chain timestamp assigner).
pub fn advance_chain_watermark(
    ops: &mut [Box<dyn OpExec>],
    from: usize,
    wm: i64,
    out: &mut Vec<Value>,
) -> Option<i64> {
    let mut cur = Some(wm);
    for i in from..ops.len() {
        let w = cur?;
        let mut fired = Vec::new();
        cur = ops[i].on_watermark(w, &mut fired);
        if fired.is_empty() {
            continue;
        }
        let mut pending = fired;
        for j in i + 1..ops.len() {
            if pending.is_empty() {
                break;
            }
            let mut next = Vec::new();
            ops[j].process(std::mem::take(&mut pending).into(), &mut next);
            pending = next;
        }
        out.append(&mut pending);
    }
    cur
}

/// Post-batch watermark poll: collects every watermark the chain's
/// operators *generated* while processing the last batch (see
/// [`OpExec::take_watermark`]), cascades each through the operators
/// downstream of its generator, and returns the highest watermark that
/// survived to the chain's edge — the one to forward to downstream
/// stages. Fired panes land in `out` alongside regular chain output.
pub fn drain_generated_watermarks(
    ops: &mut [Box<dyn OpExec>],
    out: &mut Vec<Value>,
) -> Option<i64> {
    let mut forwarded: Option<i64> = None;
    for i in 0..ops.len() {
        if let Some(wm) = ops[i].take_watermark() {
            if let Some(w) = advance_chain_watermark(ops, i + 1, wm, out) {
                forwarded = Some(forwarded.map_or(w, |f| f.max(w)));
            }
        }
    }
    forwarded
}

/// `map`.
pub struct MapExec(pub crate::graph::MapFn);
impl OpExec for MapExec {
    fn process(&mut self, input: ChainInput<'_>, out: &mut Vec<Value>) {
        out.extend(input.drain().map(|v| (self.0)(v)));
    }
}

/// `filter`.
pub struct FilterExec(pub crate::graph::FilterFn);
impl OpExec for FilterExec {
    fn process(&mut self, input: ChainInput<'_>, out: &mut Vec<Value>) {
        out.extend(input.drain().filter(|v| (self.0)(v)));
    }
}

/// `filter_map`: one pass, `None` drops the record.
pub struct FilterMapExec(pub crate::graph::FilterMapFn);
impl OpExec for FilterMapExec {
    fn process(&mut self, input: ChainInput<'_>, out: &mut Vec<Value>) {
        out.extend(input.drain().filter_map(|v| (self.0)(v)));
    }
}

/// `flat_map`.
pub struct FlatMapExec(pub crate::graph::FlatMapFn);
impl OpExec for FlatMapExec {
    fn process(&mut self, input: ChainInput<'_>, out: &mut Vec<Value>) {
        for v in input.drain() {
            out.extend((self.0)(v));
        }
    }
}

/// `key_by`: wraps each record in `Pair(key, record)`; the planner routes
/// the outgoing edge by key hash. The hashed variant records each key's
/// [`Value::stable_hash`] while the key is still in hand, so the shuffle
/// downstream reads a `u64` column instead of re-walking the pair.
pub struct KeyByExec(pub crate::graph::KeyFn);
impl OpExec for KeyByExec {
    fn process(&mut self, input: ChainInput<'_>, out: &mut Vec<Value>) {
        out.extend(input.drain().map(|v| {
            let k = (self.0)(&v);
            Value::pair(k, v)
        }));
    }
    fn process_hashed(
        &mut self,
        input: ChainInput<'_>,
        out: &mut Vec<Value>,
        hashes: &mut Vec<u64>,
    ) {
        for v in input.drain() {
            let k = (self.0)(&v);
            hashes.push(k.stable_hash());
            out.push(Value::pair(k, v));
        }
    }
}

/// The fused `key_by` of the typed front-end: the closure already emits
/// the finished `Pair(key, value)` (or `None` to suppress an undecodable
/// record). Identical to [`FilterMapExec`] except that the hashed variant
/// captures the routing hash of each emitted pair for the shuffle.
pub struct KeyByFusedExec(pub crate::graph::FilterMapFn);
impl OpExec for KeyByFusedExec {
    fn process(&mut self, input: ChainInput<'_>, out: &mut Vec<Value>) {
        out.extend(input.drain().filter_map(|v| (self.0)(v)));
    }
    fn process_hashed(
        &mut self,
        input: ChainInput<'_>,
        out: &mut Vec<Value>,
        hashes: &mut Vec<u64>,
    ) {
        for v in input.drain() {
            if let Some(p) = (self.0)(v) {
                hashes.push(crate::channels::route_hash(&p));
                out.push(p);
            }
        }
    }
}

/// Keyed `fold`: per-key accumulator, emitted as `Pair(key, acc)` at EOS.
/// Unkeyed input (non-`Pair`) folds into a single global accumulator.
pub struct FoldExec {
    init: Value,
    step: FoldFn,
    /// encoded key → (key, accumulator).
    state: FnvMap<(Value, Value)>,
    scratch: Vec<u8>,
}

impl FoldExec {
    /// Creates a fold executor.
    pub fn new(init: Value, step: FoldFn) -> Self {
        FoldExec {
            init,
            step,
            state: FnvMap::default(),
            scratch: Vec::with_capacity(32),
        }
    }
}

impl OpExec for FoldExec {
    fn process(&mut self, input: ChainInput<'_>, _out: &mut Vec<Value>) {
        for v in input.drain() {
            let (key, payload) = match v {
                Value::Pair(kp) => (kp.0, kp.1),
                other => (Value::Null, other),
            };
            let init = &self.init;
            let entry = keyed_entry(&mut self.state, &mut self.scratch, &key, |k| {
                (k.clone(), init.clone())
            });
            (self.step)(&mut entry.1, payload);
        }
    }

    fn flush(&mut self, out: &mut Vec<Value>) {
        // deterministic emission order despite the hash map
        let mut entries: Vec<(Vec<u8>, (Value, Value))> = self.state.drain().collect();
        entries.sort_by(|a, b| a.0.cmp(&b.0));
        for (_, (key, acc)) in entries {
            out.push(Value::pair(key, acc));
        }
    }

    fn snapshot(&mut self) -> Option<Value> {
        if self.state.is_empty() {
            return None;
        }
        let mut entries: Vec<(Vec<u8>, (Value, Value))> = self.state.drain().collect();
        entries.sort_by(|a, b| a.0.cmp(&b.0));
        Some(Value::List(
            entries
                .into_iter()
                .map(|(_, (key, acc))| Value::pair(key, acc))
                .collect(),
        ))
    }

    fn restore(&mut self, state: Value) {
        let Value::List(entries) = state else { return };
        for e in entries {
            let Some((key, acc)) = e.into_pair() else { continue };
            // a key restored twice (two pre-swap partials merged onto one
            // replacement) keeps the first accumulator: fold steps consume
            // elements, so partial accumulators cannot be combined
            keyed_entry(&mut self.state, &mut self.scratch, &key, |k| {
                (k.clone(), acc.clone())
            });
        }
    }
}

/// Keyed `reduce`: first-element initializer with an explicit empty
/// accumulator (`Option<Value>`), so a stream that legitimately contains
/// `Value::Null` reduces correctly — no in-band sentinel.
pub struct ReduceExec {
    f: ReduceFn,
    /// encoded key → (key, accumulator-if-any).
    state: FnvMap<(Value, Option<Value>)>,
    scratch: Vec<u8>,
}

impl ReduceExec {
    /// Creates a reduce executor.
    pub fn new(f: ReduceFn) -> Self {
        ReduceExec {
            f,
            state: FnvMap::default(),
            scratch: Vec::with_capacity(32),
        }
    }
}

impl OpExec for ReduceExec {
    fn process(&mut self, input: ChainInput<'_>, _out: &mut Vec<Value>) {
        for v in input.drain() {
            let (key, payload) = match v {
                Value::Pair(kp) => (kp.0, kp.1),
                other => (Value::Null, other),
            };
            let entry = keyed_entry(&mut self.state, &mut self.scratch, &key, |k| {
                (k.clone(), None)
            });
            entry.1 = Some(match entry.1.take() {
                None => payload,
                Some(acc) => (self.f)(&acc, &payload),
            });
        }
    }

    fn flush(&mut self, out: &mut Vec<Value>) {
        // deterministic emission order despite the hash map
        let mut entries: Vec<(Vec<u8>, (Value, Option<Value>))> = self.state.drain().collect();
        entries.sort_by(|a, b| a.0.cmp(&b.0));
        for (_, (key, acc)) in entries {
            if let Some(acc) = acc {
                out.push(Value::pair(key, acc));
            }
        }
    }

    fn snapshot(&mut self) -> Option<Value> {
        if self.state.is_empty() {
            return None;
        }
        let mut entries: Vec<(Vec<u8>, (Value, Option<Value>))> = self.state.drain().collect();
        entries.sort_by(|a, b| a.0.cmp(&b.0));
        let list: Vec<Value> = entries
            .into_iter()
            .filter_map(|(_, (key, acc))| acc.map(|a| Value::pair(key, a)))
            .collect();
        if list.is_empty() {
            None
        } else {
            Some(Value::List(list))
        }
    }

    fn restore(&mut self, state: Value) {
        let Value::List(entries) = state else { return };
        for e in entries {
            let Some((key, acc)) = e.into_pair() else { continue };
            let entry = keyed_entry(&mut self.state, &mut self.scratch, &key, |k| {
                (k.clone(), None)
            });
            // a key restored twice combines through the reduction itself —
            // reduce partials are mergeable by definition
            entry.1 = Some(match entry.1.take() {
                None => acc,
                Some(prev) => (self.f)(&prev, &acc),
            });
        }
    }
}

/// Count-based (sliding) window over a keyed stream. Emits
/// `Pair(key, aggregate)` per full window; at EOS, a final partial window
/// (if any) is emitted so no data is silently dropped.
pub struct WindowExec {
    size: usize,
    slide: usize,
    agg: WindowAgg,
    state: FnvMap<(Value, Vec<Value>)>,
    scratch: Vec<u8>,
}

impl WindowExec {
    /// Creates a window executor.
    pub fn new(size: usize, slide: usize, agg: WindowAgg) -> Self {
        WindowExec {
            size,
            slide,
            agg,
            state: FnvMap::default(),
            scratch: Vec::with_capacity(32),
        }
    }

    pub(crate) fn aggregate(agg: &WindowAgg, window: &[Value]) -> Value {
        match agg {
            WindowAgg::Mean => {
                let n = window.len().max(1) as f64;
                Value::F64(window.iter().filter_map(|v| v.as_f64()).sum::<f64>() / n)
            }
            WindowAgg::Sum => Value::F64(window.iter().filter_map(|v| v.as_f64()).sum()),
            WindowAgg::Count => Value::I64(window.len() as i64),
            WindowAgg::Max => Value::F64(
                window
                    .iter()
                    .filter_map(|v| v.as_f64())
                    .fold(f64::NEG_INFINITY, f64::max),
            ),
            WindowAgg::Min => Value::F64(
                window
                    .iter()
                    .filter_map(|v| v.as_f64())
                    .fold(f64::INFINITY, f64::min),
            ),
            WindowAgg::Collect => Value::List(window.to_vec()),
            WindowAgg::FeatureStats => {
                let xs: Vec<f32> = window
                    .iter()
                    .filter_map(|v| v.as_f64())
                    .map(|f| f as f32)
                    .collect();
                let n = xs.len().max(1) as f32;
                let mean = xs.iter().sum::<f32>() / n;
                let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f32>() / n;
                let min = xs.iter().copied().fold(f32::INFINITY, f32::min);
                let max = xs.iter().copied().fold(f32::NEG_INFINITY, f32::max);
                let last = *xs.last().unwrap_or(&0.0);
                Value::F32s(vec![mean, var.sqrt(), min, max, last])
            }
            WindowAgg::Custom(f) => f(window),
        }
    }
}

impl OpExec for WindowExec {
    fn process(&mut self, input: ChainInput<'_>, out: &mut Vec<Value>) {
        for v in input.drain() {
            let (key, payload) = match v {
                Value::Pair(kp) => (kp.0, kp.1),
                other => (Value::Null, other),
            };
            let size = self.size;
            let entry = keyed_entry(&mut self.state, &mut self.scratch, &key, |k| {
                (k.clone(), Vec::with_capacity(size))
            });
            entry.1.push(payload);
            if entry.1.len() >= self.size {
                let agg = Self::aggregate(&self.agg, &entry.1);
                out.push(Value::pair(entry.0.clone(), agg));
                entry.1.drain(..self.slide);
            }
        }
    }

    fn flush(&mut self, out: &mut Vec<Value>) {
        // deterministic emission order despite the hash map
        let mut entries: Vec<(Vec<u8>, (Value, Vec<Value>))> = self.state.drain().collect();
        entries.sort_by(|a, b| a.0.cmp(&b.0));
        for (_, (key, buf)) in entries {
            if !buf.is_empty() {
                out.push(Value::pair(key, Self::aggregate(&self.agg, &buf)));
            }
        }
    }

    fn snapshot(&mut self) -> Option<Value> {
        if self.state.is_empty() {
            return None;
        }
        let mut entries: Vec<(Vec<u8>, (Value, Vec<Value>))> = self.state.drain().collect();
        entries.sort_by(|a, b| a.0.cmp(&b.0));
        let list: Vec<Value> = entries
            .into_iter()
            .filter(|(_, (_, buf))| !buf.is_empty())
            .map(|(_, (key, buf))| Value::pair(key, Value::List(buf)))
            .collect();
        if list.is_empty() {
            None
        } else {
            Some(Value::List(list))
        }
    }

    fn restore(&mut self, state: Value) {
        let Value::List(entries) = state else { return };
        for e in entries {
            let Some((key, buf)) = e.into_pair() else { continue };
            let Value::List(buf) = buf else { continue };
            let size = self.size;
            let entry = keyed_entry(&mut self.state, &mut self.scratch, &key, |k| {
                (k.clone(), Vec::with_capacity(size))
            });
            // a key restored twice concatenates its partial windows
            entry.1.extend(buf);
        }
    }
}

/// `assign_timestamps`: extracts each record's event timestamp, feeds the
/// watermark generator, and passes the record through unchanged. The
/// runtime polls [`OpExec::take_watermark`] after every batch to pick up
/// the watermarks this operator mints. Upstream watermarks are swallowed
/// ([`OpExec::on_watermark`] returns `None`): an assigner *replaces* the
/// upstream time domain.
pub struct AssignTsExec {
    ts: TsFn,
    state: WatermarkState,
}

impl AssignTsExec {
    /// Creates a timestamp assigner with the given generator discipline.
    pub fn new(ts: TsFn, gen: WatermarkGen) -> Self {
        AssignTsExec {
            ts,
            state: WatermarkState::new(gen),
        }
    }
}

impl OpExec for AssignTsExec {
    fn process(&mut self, input: ChainInput<'_>, out: &mut Vec<Value>) {
        for v in input.drain() {
            let t = (self.ts)(&v);
            self.state.observe(&v, t);
            out.push(v);
        }
    }

    fn on_watermark(&mut self, _wm: i64, _out: &mut Vec<Value>) -> Option<i64> {
        None
    }

    fn take_watermark(&mut self) -> Option<i64> {
        self.state.take()
    }

    fn snapshot(&mut self) -> Option<Value> {
        // a single Null-keyed entry: the generator state is not keyed, so
        // after a repartition one replacement instance inherits the
        // promise and the rest restart conservatively from scratch
        Some(Value::List(vec![Value::pair(
            Value::Null,
            self.state.snapshot(),
        )]))
    }

    fn restore(&mut self, state: Value) {
        let Value::List(entries) = state else { return };
        for e in entries {
            let Some((_, s)) = e.into_pair() else { continue };
            self.state.restore(&s);
        }
    }
}

/// `side_tag`: rewrites `Pair(k, v)` into `Pair(k, Pair(I64(side), v))`
/// so the two inputs of an interval join stay distinguishable after the
/// fan-in merges them into one inbox. Keeps the key (and therefore the
/// routing hash) unchanged.
pub struct SideTagExec(pub u8);

impl SideTagExec {
    fn tag(&self, v: Value) -> Value {
        let (key, payload) = match v {
            Value::Pair(kp) => (kp.0, kp.1),
            other => (Value::Null, other),
        };
        Value::pair(key, Value::pair(Value::I64(self.0 as i64), payload))
    }
}

impl OpExec for SideTagExec {
    fn process(&mut self, input: ChainInput<'_>, out: &mut Vec<Value>) {
        out.extend(input.drain().map(|v| self.tag(v)));
    }

    fn process_hashed(
        &mut self,
        input: ChainInput<'_>,
        out: &mut Vec<Value>,
        hashes: &mut Vec<u64>,
    ) {
        for v in input.drain() {
            let p = self.tag(v);
            hashes.push(crate::channels::route_hash(&p));
            out.push(p);
        }
    }
}

/// A `(start, end, records)` span held by an event-time session window
/// or restored from a snapshot.
type Span = (i64, i64, Vec<Value>);

/// Inserts `[start, end)` with `buf` into a key's sorted span list,
/// coalescing every overlapping-or-touching span into one (the session
/// merge: two bursts within the gap become one session).
fn merge_span(spans: &mut Vec<Span>, mut start: i64, mut end: i64, mut buf: Vec<Value>) {
    let mut i = 0;
    while i < spans.len() {
        if spans[i].0 <= end && start <= spans[i].1 {
            let (s, e, b) = spans.remove(i);
            start = start.min(s);
            end = end.max(e);
            buf.extend(b);
        } else {
            i += 1;
        }
    }
    let pos = spans
        .iter()
        .position(|&(s, _, _)| s > start)
        .unwrap_or(spans.len());
    spans.insert(pos, (start, end, buf));
}

/// Event-time window over a keyed stream: buffers `Pair(key, payload)`
/// records into panes by their *event* timestamp and fires each pane
/// exactly once, when the merged input watermark passes the window's end
/// plus the allowed lateness. Records whose every window already fired
/// are *late*: counted in the `late_records` metric and, when a side
/// output is configured, routed into the tagged collector under the
/// window operator's id — observable, never silently dropped.
///
/// Snapshots carry the pane buffers *and* the operator's current
/// watermark (each entry embeds it, so any subset of repartitioned
/// entries restores the clock): a checkpoint taken between a watermark
/// and the panes it will fire neither drops nor re-fires those panes.
pub struct EventWindowExec {
    ts: TsFn,
    assigner: WindowAssigner,
    agg: WindowAgg,
    lateness_ms: i64,
    /// Merged event-time clock (`i64::MIN` until the first watermark).
    wm: i64,
    /// `(end, start)` → per-key pane buffers, fired in end order.
    panes: BTreeMap<(i64, i64), FnvMap<(Value, Vec<Value>)>>,
    /// Per-key session spans (session assigner only), sorted by start.
    sessions: FnvMap<(Value, Vec<Span>)>,
    scratch: Vec<u8>,
    metrics: Option<Metrics>,
    /// `(window op id, collector)` for the late-record side output.
    late_side: Option<(usize, Arc<Collector>)>,
}

impl EventWindowExec {
    /// Creates an event-time window executor.
    pub fn new(ts: TsFn, assigner: WindowAssigner, agg: WindowAgg, lateness_ms: i64) -> Self {
        EventWindowExec {
            ts,
            assigner,
            agg,
            lateness_ms,
            wm: i64::MIN,
            panes: BTreeMap::new(),
            sessions: FnvMap::default(),
            scratch: Vec::with_capacity(32),
            metrics: None,
            late_side: None,
        }
    }

    /// Attaches the job metrics (`late_records`).
    pub fn with_metrics(mut self, m: Metrics) -> Self {
        self.metrics = Some(m);
        self
    }

    /// Routes late records into the tagged collector under `op` (the
    /// window operator's own id) instead of only counting them.
    pub fn with_late_side(mut self, op: usize, collector: Arc<Collector>) -> Self {
        self.late_side = Some((op, collector));
        self
    }

    fn count_late(&mut self, key: Value, payload: Value) {
        if let Some(m) = &self.metrics {
            MetricsRegistry::add(&m.late_records, 1);
        }
        if let Some((op, c)) = &self.late_side {
            c.tagged
                .lock()
                .unwrap()
                .entry(*op)
                .or_default()
                .push(Value::pair(key, payload));
        }
    }

    /// Fires every pane whose `end + lateness` the clock has passed, in
    /// deterministic `(end, start, key)` order.
    fn fire_due(&mut self, out: &mut Vec<Value>) {
        while let Some((&(end, start), _)) = self.panes.iter().next() {
            if end.saturating_add(self.lateness_ms) > self.wm {
                break;
            }
            let pane = self.panes.remove(&(end, start)).expect("pane just seen");
            let mut entries: Vec<(Vec<u8>, (Value, Vec<Value>))> = pane.into_iter().collect();
            entries.sort_by(|a, b| a.0.cmp(&b.0));
            for (_, (key, buf)) in entries {
                out.push(Value::pair(key, WindowExec::aggregate(&self.agg, &buf)));
            }
        }
        if self.assigner.session_gap().is_some() && !self.sessions.is_empty() {
            let (wm, lat) = (self.wm, self.lateness_ms);
            let mut due: Vec<((i64, i64, Vec<u8>), Value, Vec<Value>)> = Vec::new();
            self.sessions.retain(|enc, (key, spans)| {
                let mut i = 0;
                while i < spans.len() {
                    if spans[i].1.saturating_add(lat) <= wm {
                        let (s, e, buf) = spans.remove(i);
                        due.push(((e, s, enc.clone()), key.clone(), buf));
                    } else {
                        i += 1;
                    }
                }
                !spans.is_empty()
            });
            due.sort_by(|a, b| a.0.cmp(&b.0));
            for (_, key, buf) in due {
                out.push(Value::pair(key, WindowExec::aggregate(&self.agg, &buf)));
            }
        }
    }
}

impl OpExec for EventWindowExec {
    fn process(&mut self, input: ChainInput<'_>, _out: &mut Vec<Value>) {
        for v in input.drain() {
            let (key, mut payload) = match v {
                Value::Pair(kp) => (kp.0, kp.1),
                other => (Value::Null, other),
            };
            let t = (self.ts)(&payload);
            if let Some(gap) = self.assigner.session_gap() {
                // a session seeded at t closes at t + gap; if the clock
                // already passed that close plus the lateness, the
                // record's session fired (or would have) — late
                if t.saturating_add(gap).saturating_add(self.lateness_ms) <= self.wm {
                    self.count_late(key, payload);
                    continue;
                }
                let entry = keyed_entry(&mut self.sessions, &mut self.scratch, &key, |k| {
                    (k.clone(), Vec::new())
                });
                merge_span(&mut entry.1, t, t.saturating_add(gap), vec![payload]);
            } else {
                let windows: Vec<(i64, i64)> = self
                    .assigner
                    .assign(t)
                    .into_iter()
                    .filter(|&(_, end)| end.saturating_add(self.lateness_ms) > self.wm)
                    .collect();
                if windows.is_empty() {
                    self.count_late(key, payload);
                    continue;
                }
                let last = windows.len() - 1;
                for (i, (start, end)) in windows.into_iter().enumerate() {
                    let p = if i == last {
                        std::mem::replace(&mut payload, Value::Null)
                    } else {
                        payload.clone()
                    };
                    let pane = self.panes.entry((end, start)).or_default();
                    let entry = keyed_entry(pane, &mut self.scratch, &key, |k| {
                        (k.clone(), Vec::new())
                    });
                    entry.1.push(p);
                }
            }
        }
    }

    fn on_watermark(&mut self, wm: i64, out: &mut Vec<Value>) -> Option<i64> {
        if wm > self.wm {
            self.wm = wm;
            self.fire_due(out);
        }
        Some(wm)
    }

    fn flush(&mut self, out: &mut Vec<Value>) {
        // end-of-stream closes every window regardless of watermarks
        self.wm = i64::MAX;
        self.fire_due(out);
    }

    fn snapshot(&mut self) -> Option<Value> {
        if self.wm == i64::MIN && self.panes.is_empty() && self.sessions.is_empty() {
            return None;
        }
        let wm = Value::I64(self.wm);
        let mut entries: Vec<Value> = Vec::new();
        // the clock itself, restorable even with no buffered panes; the
        // empty-list key is not a record key, so it cannot collide
        entries.push(Value::pair(
            Value::List(vec![]),
            Value::List(vec![wm.clone()]),
        ));
        for ((end, start), pane) in std::mem::take(&mut self.panes) {
            let mut ps: Vec<(Vec<u8>, (Value, Vec<Value>))> = pane.into_iter().collect();
            ps.sort_by(|a, b| a.0.cmp(&b.0));
            for (_, (key, buf)) in ps {
                entries.push(Value::pair(
                    Value::List(vec![key]),
                    Value::List(vec![
                        wm.clone(),
                        Value::I64(start),
                        Value::I64(end),
                        Value::List(buf),
                    ]),
                ));
            }
        }
        let mut ss: Vec<(Vec<u8>, (Value, Vec<Span>))> =
            std::mem::take(&mut self.sessions).into_iter().collect();
        ss.sort_by(|a, b| a.0.cmp(&b.0));
        for (_, (key, spans)) in ss {
            for (start, end, buf) in spans {
                entries.push(Value::pair(
                    Value::List(vec![key.clone()]),
                    Value::List(vec![
                        wm.clone(),
                        Value::I64(start),
                        Value::I64(end),
                        Value::List(buf),
                    ]),
                ));
            }
        }
        Some(Value::List(entries))
    }

    fn restore(&mut self, state: Value) {
        let Value::List(entries) = state else { return };
        for e in entries {
            let Some((key, body)) = e.into_pair() else { continue };
            let Value::List(mut body) = body else { continue };
            let Value::List(mut key) = key else { continue };
            // every entry carries the snapshot clock: max-merging keeps
            // already-fired panes from re-forming out of replayed records
            if let Some(w) = body.first().and_then(Value::as_i64) {
                self.wm = self.wm.max(w);
            }
            if key.is_empty() || body.len() < 4 {
                continue;
            }
            let key = key.remove(0);
            let (Some(start), Some(end)) = (
                body.get(1).and_then(Value::as_i64),
                body.get(2).and_then(Value::as_i64),
            ) else {
                continue;
            };
            let Value::List(buf) = body.remove(3) else { continue };
            if self.assigner.session_gap().is_some() {
                let entry = keyed_entry(&mut self.sessions, &mut self.scratch, &key, |k| {
                    (k.clone(), Vec::new())
                });
                merge_span(&mut entry.1, start, end, buf);
            } else {
                let pane = self.panes.entry((end, start)).or_default();
                let entry = keyed_entry(pane, &mut self.scratch, &key, |k| {
                    (k.clone(), Vec::new())
                });
                // a key restored twice concatenates its partial panes
                entry.1.extend(buf);
            }
        }
    }
}

/// Keyed stream-stream interval join: a left record at `tl` matches
/// right records with the same key whose timestamp lies in
/// `[tl + lower, tl + upper]`. Each arrival scans the opposite side's
/// buffer and emits `Pair(key, Pair(left, right))` per match, then
/// buffers itself — every match is emitted exactly once, by whichever
/// side arrives second. The merged watermark (min across both inputs,
/// courtesy of the shared inbox) drives eviction: a left is dead once
/// `tl + upper < wm`, a right once `tr < wm + lower`. Records arriving
/// past their own eviction horizon are counted late and dropped.
pub struct IntervalJoinExec {
    ts_left: TsFn,
    ts_right: TsFn,
    lower_ms: i64,
    upper_ms: i64,
    /// encoded key → (key, left (ts, payload) buffer, right buffer).
    state: FnvMap<(Value, Vec<(i64, Value)>, Vec<(i64, Value)>)>,
    scratch: Vec<u8>,
    wm: i64,
    metrics: Option<Metrics>,
}

impl IntervalJoinExec {
    /// Creates an interval-join executor.
    pub fn new(ts_left: TsFn, ts_right: TsFn, lower_ms: i64, upper_ms: i64) -> Self {
        IntervalJoinExec {
            ts_left,
            ts_right,
            lower_ms,
            upper_ms,
            state: FnvMap::default(),
            scratch: Vec::with_capacity(32),
            wm: i64::MIN,
            metrics: None,
        }
    }

    /// Attaches the job metrics (`late_records`).
    pub fn with_metrics(mut self, m: Metrics) -> Self {
        self.metrics = Some(m);
        self
    }
}

impl OpExec for IntervalJoinExec {
    fn process(&mut self, input: ChainInput<'_>, out: &mut Vec<Value>) {
        for v in input.drain() {
            // Pair(key, Pair(I64(side), payload)) — see SideTagExec
            let Value::Pair(kp) = v else { continue };
            let (key, tagged) = (kp.0, kp.1);
            let Value::Pair(sp) = tagged else { continue };
            let (side, payload) = (sp.0, sp.1);
            let left = side.as_i64() == Some(0);
            let t = if left {
                (self.ts_left)(&payload)
            } else {
                (self.ts_right)(&payload)
            };
            let evicted = if left {
                self.wm != i64::MIN && t.saturating_add(self.upper_ms) < self.wm
            } else {
                self.wm != i64::MIN && t < self.wm.saturating_add(self.lower_ms)
            };
            if evicted {
                if let Some(m) = &self.metrics {
                    MetricsRegistry::add(&m.late_records, 1);
                }
                continue;
            }
            let entry = keyed_entry(&mut self.state, &mut self.scratch, &key, |k| {
                (k.clone(), Vec::new(), Vec::new())
            });
            if left {
                for (tr, r) in &entry.2 {
                    if *tr >= t.saturating_add(self.lower_ms)
                        && *tr <= t.saturating_add(self.upper_ms)
                    {
                        out.push(Value::pair(
                            entry.0.clone(),
                            Value::pair(payload.clone(), r.clone()),
                        ));
                    }
                }
                entry.1.push((t, payload));
            } else {
                for (tl, l) in &entry.1 {
                    if t >= tl.saturating_add(self.lower_ms)
                        && t <= tl.saturating_add(self.upper_ms)
                    {
                        out.push(Value::pair(
                            entry.0.clone(),
                            Value::pair(l.clone(), payload.clone()),
                        ));
                    }
                }
                entry.2.push((t, payload));
            }
        }
    }

    fn on_watermark(&mut self, wm: i64, _out: &mut Vec<Value>) -> Option<i64> {
        if wm > self.wm {
            self.wm = wm;
            let (w, lower, upper) = (self.wm, self.lower_ms, self.upper_ms);
            self.state.retain(|_, (_, lefts, rights)| {
                lefts.retain(|(tl, _)| tl.saturating_add(upper) >= w);
                rights.retain(|(tr, _)| *tr >= w.saturating_add(lower));
                !lefts.is_empty() || !rights.is_empty()
            });
        }
        Some(wm)
    }

    fn snapshot(&mut self) -> Option<Value> {
        if self.wm == i64::MIN && self.state.is_empty() {
            return None;
        }
        let wm = Value::I64(self.wm);
        let mut entries: Vec<Value> = vec![Value::pair(
            Value::List(vec![]),
            Value::List(vec![wm.clone()]),
        )];
        let mut st: Vec<(Vec<u8>, (Value, Vec<(i64, Value)>, Vec<(i64, Value)>))> =
            std::mem::take(&mut self.state).into_iter().collect();
        st.sort_by(|a, b| a.0.cmp(&b.0));
        let side = |buf: Vec<(i64, Value)>| {
            Value::List(
                buf.into_iter()
                    .map(|(t, p)| Value::pair(Value::I64(t), p))
                    .collect(),
            )
        };
        for (_, (key, lefts, rights)) in st {
            entries.push(Value::pair(
                Value::List(vec![key]),
                Value::List(vec![wm.clone(), side(lefts), side(rights)]),
            ));
        }
        Some(Value::List(entries))
    }

    fn restore(&mut self, state: Value) {
        let Value::List(entries) = state else { return };
        let parse_side = |v: Value| -> Vec<(i64, Value)> {
            let Value::List(items) = v else { return Vec::new() };
            items
                .into_iter()
                .filter_map(|e| {
                    let (t, p) = e.into_pair()?;
                    Some((t.as_i64()?, p))
                })
                .collect()
        };
        for e in entries {
            let Some((key, body)) = e.into_pair() else { continue };
            let Value::List(mut body) = body else { continue };
            let Value::List(mut key) = key else { continue };
            if let Some(w) = body.first().and_then(Value::as_i64) {
                self.wm = self.wm.max(w);
            }
            if key.is_empty() || body.len() < 3 {
                continue;
            }
            let key = key.remove(0);
            let rights = parse_side(body.remove(2));
            let lefts = parse_side(body.remove(1));
            let entry = keyed_entry(&mut self.state, &mut self.scratch, &key, |k| {
                (k.clone(), Vec::new(), Vec::new())
            });
            entry.1.extend(lefts);
            entry.2.extend(rights);
        }
    }
}

/// Shared sink collector: `collect` sinks append here, `count` sinks only
/// bump the counter.
#[derive(Debug, Default)]
pub struct Collector {
    /// Collected values (for `SinkKind::Collect`).
    pub values: Mutex<Vec<Value>>,
    /// Values collected by tagged (typed) sinks, keyed by sink operator
    /// id; redeemed per `CollectHandle` through `JobReport::take`.
    pub tagged: Mutex<BTreeMap<usize, Vec<Value>>>,
    /// Count of all events that reached any sink.
    pub count: AtomicU64,
}

/// Terminal sink executor.
pub struct SinkExec {
    kind: SinkKind,
    /// Logical operator id of this sink (tags typed collects).
    op: usize,
    collector: Arc<Collector>,
    metrics: Metrics,
}

impl SinkExec {
    /// Creates a sink executor for the sink at logical operator id `op`.
    pub fn new(kind: SinkKind, op: usize, collector: Arc<Collector>, metrics: Metrics) -> Self {
        SinkExec {
            kind,
            op,
            collector,
            metrics,
        }
    }
}

impl OpExec for SinkExec {
    fn process(&mut self, input: ChainInput<'_>, _out: &mut Vec<Value>) {
        let n = input.len() as u64;
        MetricsRegistry::add(&self.metrics.events_out, n);
        self.collector
            .count
            .fetch_add(n, std::sync::atomic::Ordering::Relaxed);
        // only the collecting kinds materialise the payload; Count/Discard
        // sinks stay zero-copy even when the batch is shared with sibling
        // edges
        match self.kind {
            SinkKind::Collect => self
                .collector
                .values
                .lock()
                .unwrap()
                .extend(input.drain()),
            SinkKind::CollectTagged => self
                .collector
                .tagged
                .lock()
                .unwrap()
                .entry(self.op)
                .or_default()
                .extend(input.drain()),
            SinkKind::Count | SinkKind::Discard => {}
        }
    }

    fn process_columns(&mut self, input: ColumnBatch) -> ColumnFlow {
        // the non-collecting kinds only need the row count — no reason
        // to materialize Value rows; the collecting kinds fall back so
        // the collectors keep receiving plain Values
        match self.kind {
            SinkKind::Count | SinkKind::Discard => {
                let n = input.len() as u64;
                MetricsRegistry::add(&self.metrics.events_out, n);
                self.collector
                    .count
                    .fetch_add(n, std::sync::atomic::Ordering::Relaxed);
                ColumnFlow::Rows(Vec::new())
            }
            SinkKind::Collect | SinkKind::CollectTagged => ColumnFlow::Fallback(input),
        }
    }
}

/// Batched inference through a loaded XLA artifact. Buffers feature rows
/// (`F32s` or `Pair(key, F32s)`), executes one PJRT call per full batch,
/// and re-emits rows with the model output as payload. The final partial
/// batch is zero-padded, executed, and un-padded at flush.
pub struct XlaExec {
    artifact: Arc<super::xla_exec::Artifact>,
    batch: usize,
    in_dim: usize,
    keys: Vec<Option<Value>>,
    rows: Vec<f32>,
    metrics: Metrics,
}

impl XlaExec {
    /// Creates an executor bound to a loaded artifact.
    pub fn new(
        artifact: Arc<super::xla_exec::Artifact>,
        batch: usize,
        in_dim: usize,
        metrics: Metrics,
    ) -> Self {
        XlaExec {
            artifact,
            batch,
            in_dim,
            keys: Vec::new(),
            rows: Vec::new(),
            metrics,
        }
    }

    fn run_buffer(&mut self, out: &mut Vec<Value>) {
        // chunked: a buffer restored from a dynamic-update handoff may
        // hold more than one compiled batch worth of rows
        while !self.keys.is_empty() {
            let n = self.keys.len().min(self.batch);
            let keys: Vec<Option<Value>> = self.keys.drain(..n).collect();
            let mut rows: Vec<f32> = self.rows.drain(..n * self.in_dim).collect();
            // zero-pad to the compiled batch size
            rows.resize(self.batch * self.in_dim, 0.0);
            let outputs = self
                .artifact
                .execute_f32(&rows, self.batch, self.in_dim)
                .expect("xla execution failed on hot path");
            MetricsRegistry::add(&self.metrics.xla_calls, 1);
            MetricsRegistry::add(&self.metrics.xla_rows, n as u64);
            let out_dim = outputs.len() / self.batch;
            for (i, key) in keys.into_iter().enumerate() {
                let row = outputs[i * out_dim..(i + 1) * out_dim].to_vec();
                let payload = Value::F32s(row);
                out.push(match key {
                    Some(k) => Value::pair(k, payload),
                    None => payload,
                });
            }
        }
    }
}

impl OpExec for XlaExec {
    fn process(&mut self, input: ChainInput<'_>, out: &mut Vec<Value>) {
        for v in input.drain() {
            let (key, payload) = match v {
                Value::Pair(kp) => (Some(kp.0), kp.1),
                other => (None, other),
            };
            let feats = match &payload {
                Value::F32s(f) => f.as_slice(),
                other => panic!("XlaMap expects F32s feature rows, got {other:?}"),
            };
            assert_eq!(
                feats.len(),
                self.in_dim,
                "feature row dim {} != compiled in_dim {}",
                feats.len(),
                self.in_dim
            );
            self.rows.extend_from_slice(feats);
            self.keys.push(key);
            if self.keys.len() >= self.batch {
                self.run_buffer(out);
            }
        }
    }

    fn flush(&mut self, out: &mut Vec<Value>) {
        self.run_buffer(out);
    }

    fn snapshot(&mut self) -> Option<Value> {
        if self.keys.is_empty() {
            return None;
        }
        let rows = std::mem::take(&mut self.rows);
        let keys = std::mem::take(&mut self.keys);
        let entries: Vec<Value> = keys
            .into_iter()
            .enumerate()
            .map(|(i, key)| {
                let row = rows[i * self.in_dim..(i + 1) * self.in_dim].to_vec();
                // the optional key is wrapped in a list so a genuine
                // Value::Null key stays distinguishable from "no key"
                let key = Value::List(key.into_iter().collect());
                Value::pair(key, Value::F32s(row))
            })
            .collect();
        Some(Value::List(entries))
    }

    fn restore(&mut self, state: Value) {
        let Value::List(entries) = state else { return };
        for e in entries {
            let Some((key, row)) = e.into_pair() else { continue };
            let Value::F32s(row) = row else { continue };
            if row.len() != self.in_dim {
                continue;
            }
            self.rows.extend_from_slice(&row);
            self.keys.push(match key {
                Value::List(mut l) if !l.is_empty() => Some(l.remove(0)),
                _ => None,
            });
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    fn chain_of(ops: Vec<Box<dyn OpExec>>) -> Vec<Box<dyn OpExec>> {
        ops
    }

    fn run(ops: &mut [Box<dyn OpExec>], batch: Batch) -> Batch {
        run_chain(ops, batch, &mut ChainBuffers::new(None))
    }

    // the standard FNV-1a parameters, asserted against the shared hasher
    // like the crc32 known-vector test
    const FNV_OFFSET: u64 = 0xcbf2_9ce4_8422_2325;
    const FNV_PRIME: u64 = 0x1_0000_01b3;

    #[test]
    fn fnv_hasher_initialization_is_explicit() {
        // no writes: the state is the offset basis, never 0
        assert_eq!(Fnv1a::new().finish(), FNV_OFFSET);
        // incremental writes equal one-shot writes wherever the boundary
        // falls (the old exec-local impl re-seeded if a boundary landed
        // on state 0)
        let mut one = Fnv1a::new();
        one.write(b"flowunits");
        for split in 0..=9 {
            let mut two = Fnv1a::new();
            two.write(&b"flowunits"[..split]);
            two.write(&b"flowunits"[split..]);
            assert_eq!(one.finish(), two.finish(), "split at {split}");
        }
    }

    #[test]
    fn fnv_hasher_zero_state_is_not_reseeded() {
        // Drive the state through 0 (the seam stands in for a byte string
        // whose intermediate FNV state is exactly 0 — such strings exist
        // but are not hand-derivable) and keep writing: the next byte
        // must hash from 0, not from a silently re-seeded offset basis.
        let mut h = Fnv1a::from_state(0);
        h.write(&[0x61]);
        assert_eq!(h.finish(), 0x61u64.wrapping_mul(FNV_PRIME));
        let mut reseeded = Fnv1a::new();
        reseeded.write(&[0x61]);
        assert_ne!(h.finish(), reseeded.finish());
    }

    #[test]
    fn key_by_fills_the_hash_column() {
        let mut ops = chain_of(vec![Box::new(KeyByExec(Arc::new(|v: &Value| {
            Value::I64(v.as_i64().unwrap() % 2)
        })))]);
        let out = run(&mut ops, vec![Value::I64(4), Value::I64(7)].into());
        let hs = out.key_hashes().expect("keying chain attaches the column");
        assert_eq!(hs.len(), 2);
        assert_eq!(hs[0], Value::I64(0).stable_hash());
        assert_eq!(hs[1], Value::I64(1).stable_hash());
        // and the column matches what the shuffle would recompute
        for (v, &h) in out.values().iter().zip(hs) {
            assert_eq!(crate::channels::route_hash(v), h);
        }
    }

    #[test]
    fn key_by_fused_fills_the_hash_column_and_drops_none() {
        let mut ops = chain_of(vec![Box::new(KeyByFusedExec(Arc::new(
            |v: Value| -> Option<Value> {
                let n = v.as_i64()?;
                if n % 3 == 0 {
                    return None; // suppressed record
                }
                Some(Value::pair(Value::I64(n % 2), v))
            },
        )))]);
        let out = run(&mut ops, (0..6).map(Value::I64).collect::<Vec<_>>().into());
        // 0 and 3 suppressed
        assert_eq!(out.len(), 4);
        let hs = out.key_hashes().expect("column aligned with survivors");
        for (v, &h) in out.values().iter().zip(hs) {
            assert_eq!(crate::channels::route_hash(v), h);
        }
    }

    #[test]
    fn non_keying_chain_attaches_no_hash_column() {
        let mut ops = chain_of(vec![Box::new(MapExec(Arc::new(|v| v)))]);
        let out = run(&mut ops, vec![Value::I64(1)].into());
        assert!(out.key_hashes().is_none());
    }

    #[test]
    fn map_filter_flatmap_chain() {
        let mut ops = chain_of(vec![
            Box::new(FlatMapExec(Arc::new(|v: Value| {
                let n = v.as_i64().unwrap();
                vec![Value::I64(n), Value::I64(n + 100)]
            }))),
            Box::new(FilterExec(Arc::new(|v: &Value| v.as_i64().unwrap() % 2 == 0))),
            Box::new(MapExec(Arc::new(|v: Value| {
                Value::I64(v.as_i64().unwrap() * 10)
            }))),
        ]);
        let out = run(&mut ops, vec![Value::I64(1), Value::I64(2)].into());
        // 1 -> [1, 101] filtered out; 2 -> [2, 102] -> [20, 1020]
        assert_eq!(out, vec![Value::I64(20), Value::I64(1020)]);
        assert!(flush_chain(&mut ops).is_empty());
    }

    #[test]
    fn empty_chain_passes_batch_through_by_identity() {
        let mut ops: Vec<Box<dyn OpExec>> = vec![];
        let b = Batch::new(vec![Value::I64(1), Value::I64(2)]);
        let twin = b.clone();
        let out = run(&mut ops, b);
        assert!(
            Batch::ptr_eq(&out, &twin),
            "a forwarding stage moves the handle, it does not copy the payload"
        );
    }

    #[test]
    fn keyed_fold_counts_words() {
        let mut ops = chain_of(vec![
            Box::new(KeyByExec(Arc::new(|v: &Value| v.clone()))),
            Box::new(FoldExec::new(
                Value::I64(0),
                Arc::new(|acc: &mut Value, _| {
                    *acc = Value::I64(acc.as_i64().unwrap() + 1);
                }),
            )),
        ]);
        let words: Vec<Value> = ["a", "b", "a", "c", "a", "b"]
            .iter()
            .map(|w| Value::Str(w.to_string()))
            .collect();
        let mid = run(&mut ops, words.into());
        assert!(mid.is_empty(), "fold holds state until flush");
        let mut out = flush_chain(&mut ops);
        out.sort_by_key(|v| v.as_pair().unwrap().0.as_str().unwrap().to_string());
        let counts: Vec<(String, i64)> = out
            .iter()
            .map(|v| {
                let (k, c) = v.as_pair().unwrap();
                (k.as_str().unwrap().to_string(), c.as_i64().unwrap())
            })
            .collect();
        assert_eq!(
            counts,
            vec![("a".into(), 3), ("b".into(), 2), ("c".into(), 1)]
        );
    }

    #[test]
    fn unkeyed_fold_uses_global_key() {
        let mut f = FoldExec::new(
            Value::F64(0.0),
            Arc::new(|acc: &mut Value, v| {
                *acc = Value::F64(acc.as_f64().unwrap() + v.as_f64().unwrap());
            }),
        );
        let mut out = Vec::new();
        f.process(vec![Value::F64(1.5), Value::F64(2.5)].into(), &mut out);
        f.flush(&mut out);
        assert_eq!(out, vec![Value::pair(Value::Null, Value::F64(4.0))]);
    }

    #[test]
    fn reduce_handles_null_values_without_sentinel_corruption() {
        // a stream that genuinely contains Value::Null must reduce it like
        // any other value (the old fold-based sugar used Null as an
        // in-band "empty" sentinel and silently dropped it)
        let mut r = ReduceExec::new(Arc::new(|a: &Value, b: &Value| {
            let count = |v: &Value| if matches!(v, Value::Null) { 1 } else { v.as_i64().unwrap_or(0) };
            Value::I64(count(a) + count(b))
        }));
        let mut out = Vec::new();
        r.process(
            vec![
                Value::pair(Value::I64(0), Value::Null),
                Value::pair(Value::I64(0), Value::Null),
                Value::pair(Value::I64(0), Value::Null),
            ]
            .into(),
            &mut out,
        );
        r.flush(&mut out);
        assert_eq!(out.len(), 1);
        // 3 nulls: first initializes the accumulator (Null), the two
        // combining steps each count both sides: (1+1)=2, then (2+1)=3
        assert_eq!(out[0].as_pair().unwrap().1.as_i64(), Some(3));
    }

    #[test]
    fn reduce_emits_nothing_for_empty_stream() {
        let mut r = ReduceExec::new(Arc::new(|a: &Value, _b: &Value| a.clone()));
        let mut out = Vec::new();
        r.flush(&mut out);
        assert!(out.is_empty());
    }

    #[test]
    fn tumbling_window_mean() {
        let mut w = WindowExec::new(4, 4, WindowAgg::Mean);
        let mut out = Vec::new();
        let keyed: Vec<Value> = (0..8)
            .map(|i| Value::pair(Value::I64(i % 2), Value::F64(i as f64)))
            .collect();
        w.process(keyed.into(), &mut out);
        // key 0: [0,2,4,6] mean 3; key 1: [1,3,5,7] mean 4
        assert_eq!(out.len(), 2);
        let find = |k: i64| {
            out.iter()
                .find(|v| v.as_pair().unwrap().0.as_i64() == Some(k))
                .unwrap()
                .as_pair()
                .unwrap()
                .1
                .as_f64()
                .unwrap()
        };
        assert_eq!(find(0), 3.0);
        assert_eq!(find(1), 4.0);
        let mut rest = Vec::new();
        w.flush(&mut rest);
        assert!(rest.is_empty(), "no partials after exact tumble");
    }

    #[test]
    fn sliding_window_overlaps() {
        let mut w = WindowExec::new(3, 1, WindowAgg::Sum);
        let mut out = Vec::new();
        let vals: Vec<Value> = (1..=5).map(|i| Value::F64(i as f64)).collect();
        w.process(vals.into(), &mut out);
        // windows [1,2,3]=6, [2,3,4]=9, [3,4,5]=12
        let sums: Vec<f64> = out
            .iter()
            .map(|v| v.as_pair().unwrap().1.as_f64().unwrap())
            .collect();
        assert_eq!(sums, vec![6.0, 9.0, 12.0]);
    }

    #[test]
    fn window_flush_emits_partial() {
        let mut w = WindowExec::new(10, 10, WindowAgg::Count);
        let mut out = Vec::new();
        w.process(vec![Value::F64(1.0); 3].into(), &mut out);
        assert!(out.is_empty());
        w.flush(&mut out);
        assert_eq!(out.len(), 1);
        assert_eq!(out[0].as_pair().unwrap().1.as_i64(), Some(3));
    }

    #[test]
    fn feature_stats_shape_and_values() {
        let v = WindowExec::aggregate(
            &WindowAgg::FeatureStats,
            &[Value::F64(1.0), Value::F64(3.0)],
        );
        let f = v.as_f32s().unwrap();
        assert_eq!(f.len(), 5);
        assert_eq!(f[0], 2.0); // mean
        assert_eq!(f[1], 1.0); // std
        assert_eq!(f[2], 1.0); // min
        assert_eq!(f[3], 3.0); // max
        assert_eq!(f[4], 3.0); // last
    }

    #[test]
    fn window_min_max_aggregates() {
        let vals = [Value::F64(4.0), Value::F64(-1.0), Value::F64(2.0)];
        assert_eq!(
            WindowExec::aggregate(&WindowAgg::Max, &vals),
            Value::F64(4.0)
        );
        assert_eq!(
            WindowExec::aggregate(&WindowAgg::Min, &vals),
            Value::F64(-1.0)
        );
    }

    #[test]
    fn custom_window_aggregate() {
        let agg = WindowAgg::Custom(Arc::new(|w: &[Value]| Value::I64(w.len() as i64 * 100)));
        assert_eq!(
            WindowExec::aggregate(&agg, &[Value::Null, Value::Null]),
            Value::I64(200)
        );
    }

    #[test]
    fn sink_collects_and_counts() {
        let collector = Arc::new(Collector::default());
        let m = crate::metrics::MetricsRegistry::new();
        let mut sink = SinkExec::new(SinkKind::Collect, 0, collector.clone(), m.clone());
        let mut out = Vec::new();
        sink.process(vec![Value::I64(1), Value::I64(2)].into(), &mut out);
        assert!(out.is_empty());
        assert_eq!(collector.values.lock().unwrap().len(), 2);
        assert_eq!(
            collector.count.load(std::sync::atomic::Ordering::Relaxed),
            2
        );
        assert_eq!(m.events_out.load(std::sync::atomic::Ordering::Relaxed), 2);
    }

    #[test]
    fn tagged_sinks_segregate_by_op_id() {
        let collector = Arc::new(Collector::default());
        let m = crate::metrics::MetricsRegistry::new();
        let mut a = SinkExec::new(SinkKind::CollectTagged, 7, collector.clone(), m.clone());
        let mut b = SinkExec::new(SinkKind::CollectTagged, 9, collector.clone(), m.clone());
        let mut out = Vec::new();
        a.process(vec![Value::I64(1), Value::I64(2)].into(), &mut out);
        b.process(vec![Value::Str("x".into())].into(), &mut out);
        let tagged = collector.tagged.lock().unwrap();
        assert_eq!(tagged[&7], vec![Value::I64(1), Value::I64(2)]);
        assert_eq!(tagged[&9], vec![Value::Str("x".into())]);
        assert!(
            collector.values.lock().unwrap().is_empty(),
            "tagged values never leak into the flat collection"
        );
        assert_eq!(m.events_out.load(std::sync::atomic::Ordering::Relaxed), 3);
    }

    #[test]
    fn reduce_snapshot_restore_roundtrips_state() {
        let sum = |a: &Value, b: &Value| Value::I64(a.as_i64().unwrap() + b.as_i64().unwrap());
        let mut r1 = ReduceExec::new(Arc::new(sum));
        let mut out = Vec::new();
        r1.process(
            vec![
                Value::pair(Value::I64(1), Value::I64(10)),
                Value::pair(Value::I64(2), Value::I64(20)),
                Value::pair(Value::I64(1), Value::I64(5)),
            ]
            .into(),
            &mut out,
        );
        let snap = r1.snapshot().expect("held state");
        assert!(r1.snapshot().is_none(), "snapshot drains the incarnation");
        let mut r2 = ReduceExec::new(Arc::new(sum));
        r2.restore(snap);
        let mut restored = Vec::new();
        r2.flush(&mut restored);
        restored.sort_by_key(|v| v.as_pair().unwrap().0.as_i64().unwrap());
        assert_eq!(
            restored,
            vec![
                Value::pair(Value::I64(1), Value::I64(15)),
                Value::pair(Value::I64(2), Value::I64(20)),
            ]
        );
    }

    #[test]
    fn reduce_restore_merges_duplicate_keys_through_the_reduction() {
        let sum = |a: &Value, b: &Value| Value::I64(a.as_i64().unwrap() + b.as_i64().unwrap());
        let mut r = ReduceExec::new(Arc::new(sum));
        r.restore(Value::List(vec![
            Value::pair(Value::I64(0), Value::I64(3)),
            Value::pair(Value::I64(0), Value::I64(4)),
        ]));
        let mut out = Vec::new();
        r.flush(&mut out);
        assert_eq!(out, vec![Value::pair(Value::I64(0), Value::I64(7))]);
    }

    #[test]
    fn window_snapshot_restore_preserves_partial_buffers() {
        let mut w1 = WindowExec::new(4, 4, WindowAgg::Sum);
        let mut out = Vec::new();
        w1.process(
            vec![
                Value::pair(Value::I64(0), Value::F64(1.0)),
                Value::pair(Value::I64(0), Value::F64(2.0)),
            ]
            .into(),
            &mut out,
        );
        assert!(out.is_empty(), "window not full yet");
        let snap = w1.snapshot().expect("partial buffer held");
        let mut w2 = WindowExec::new(4, 4, WindowAgg::Sum);
        w2.restore(snap);
        // two more events complete the window across the handoff
        w2.process(
            vec![
                Value::pair(Value::I64(0), Value::F64(3.0)),
                Value::pair(Value::I64(0), Value::F64(4.0)),
            ]
            .into(),
            &mut out,
        );
        assert_eq!(out, vec![Value::pair(Value::I64(0), Value::F64(10.0))]);
    }

    #[test]
    fn fold_snapshot_restore_roundtrips_counts() {
        let step = |acc: &mut Value, _v: Value| {
            *acc = Value::I64(acc.as_i64().unwrap() + 1);
        };
        let mut f1 = FoldExec::new(Value::I64(0), Arc::new(step));
        let mut out = Vec::new();
        f1.process(
            vec![Value::pair(Value::Str("a".into()), Value::Null); 3].into(),
            &mut out,
        );
        let snap = f1.snapshot().expect("held state");
        let mut f2 = FoldExec::new(Value::I64(0), Arc::new(step));
        f2.restore(snap);
        f2.process(
            vec![Value::pair(Value::Str("a".into()), Value::Null); 2].into(),
            &mut out,
        );
        f2.flush(&mut out);
        assert_eq!(
            out,
            vec![Value::pair(Value::Str("a".into()), Value::I64(5))]
        );
    }

    #[test]
    fn stateless_ops_snapshot_nothing() {
        let mut m = MapExec(Arc::new(|v| v));
        assert!(m.snapshot().is_none());
        let mut r = ReduceExec::new(Arc::new(|a: &Value, _: &Value| a.clone()));
        assert!(r.snapshot().is_none(), "empty state snapshots as None");
    }

    fn id_ts() -> crate::time::TsFn {
        Arc::new(|v: &Value| v.as_i64().unwrap_or(0))
    }

    fn keyed(k: i64, t: i64) -> Value {
        Value::pair(Value::I64(k), Value::I64(t))
    }

    #[test]
    fn assign_ts_passes_records_and_mints_watermarks() {
        let mut a = AssignTsExec::new(id_ts(), WatermarkGen::BoundedOutOfOrderness { bound_ms: 10 });
        let mut out = Vec::new();
        a.process(vec![Value::I64(100), Value::I64(50)].into(), &mut out);
        assert_eq!(out, vec![Value::I64(100), Value::I64(50)]);
        assert_eq!(a.take_watermark(), Some(90));
        assert_eq!(a.take_watermark(), None, "no advance, no re-emit");
        // upstream watermarks are swallowed: this assigner owns the clock
        assert_eq!(a.on_watermark(500, &mut out), None);
    }

    #[test]
    fn event_window_fires_once_when_watermark_passes_end_plus_lateness() {
        let mut w = EventWindowExec::new(
            id_ts(),
            WindowAssigner::Tumbling { size_ms: 10 },
            WindowAgg::Count,
            5,
        );
        let mut out = Vec::new();
        w.process(vec![keyed(0, 1), keyed(0, 9), keyed(1, 3)].into(), &mut out);
        assert!(out.is_empty(), "panes buffer until the watermark");
        // end=10, lateness=5: watermark 14 is not yet due
        assert_eq!(w.on_watermark(14, &mut out), Some(14));
        assert!(out.is_empty());
        assert_eq!(w.on_watermark(15, &mut out), Some(15));
        out.sort_by_key(|v| v.as_pair().unwrap().0.as_i64().unwrap());
        assert_eq!(
            out,
            vec![
                Value::pair(Value::I64(0), Value::I64(2)),
                Value::pair(Value::I64(1), Value::I64(1)),
            ]
        );
        // a second watermark must not re-fire the pane
        let mut again = Vec::new();
        w.on_watermark(100, &mut again);
        assert!(again.is_empty());
    }

    #[test]
    fn event_window_counts_late_records_and_routes_side_output() {
        let collector = Arc::new(Collector::default());
        let m = crate::metrics::MetricsRegistry::new();
        let mut w = EventWindowExec::new(
            id_ts(),
            WindowAssigner::Tumbling { size_ms: 10 },
            WindowAgg::Count,
            0,
        )
        .with_metrics(m.clone())
        .with_late_side(42, collector.clone());
        let mut out = Vec::new();
        w.on_watermark(20, &mut out);
        // ts=5 falls in [0,10), which fired (vacuously) at wm=20: late
        w.process(vec![keyed(7, 5)].into(), &mut out);
        assert!(out.is_empty());
        assert_eq!(m.late_records.load(std::sync::atomic::Ordering::Relaxed), 1);
        assert_eq!(
            collector.tagged.lock().unwrap()[&42],
            vec![keyed(7, 5)],
            "late record observable on the side output, key intact"
        );
        // ts=25 is on time and fires at flush
        w.process(vec![keyed(7, 25)].into(), &mut out);
        w.flush(&mut out);
        assert_eq!(out, vec![Value::pair(Value::I64(7), Value::I64(1))]);
    }

    #[test]
    fn event_window_within_lateness_still_lands_in_pane() {
        let mut w = EventWindowExec::new(
            id_ts(),
            WindowAssigner::Tumbling { size_ms: 10 },
            WindowAgg::Count,
            5,
        );
        let mut out = Vec::new();
        w.process(vec![keyed(0, 3)].into(), &mut out);
        w.on_watermark(12, &mut out);
        assert!(out.is_empty(), "end=10 holds open until 15");
        // ts=8 arrives after the watermark passed the window end but
        // within the allowed lateness: incorporated, not late
        w.process(vec![keyed(0, 8)].into(), &mut out);
        w.on_watermark(15, &mut out);
        assert_eq!(out, vec![Value::pair(Value::I64(0), Value::I64(2))]);
    }

    #[test]
    fn session_window_merges_bursts_within_gap() {
        let mut w = EventWindowExec::new(
            id_ts(),
            WindowAssigner::Session { gap_ms: 10 },
            WindowAgg::Count,
            0,
        );
        let mut out = Vec::new();
        // two bursts for key 0: {1, 5} and {30} (gap > 10 between them);
        // out-of-order arrival must not change the sessionization
        w.process(
            vec![keyed(0, 5), keyed(0, 30), keyed(0, 1)].into(),
            &mut out,
        );
        assert!(out.is_empty());
        // first session [1, 15) closes once the clock passes 15
        w.on_watermark(15, &mut out);
        assert_eq!(out, vec![Value::pair(Value::I64(0), Value::I64(2))]);
        out.clear();
        w.flush(&mut out);
        assert_eq!(out, vec![Value::pair(Value::I64(0), Value::I64(1))]);
    }

    /// A checkpoint epoch marker lands *between* a watermark and the
    /// window firing it will cause: the snapshot must carry both the
    /// pane buffers and the current watermark, so the restored
    /// incarnation fires the pane exactly once — neither dropped (buffers
    /// lost) nor duplicated (clock lost, pane re-formed from replay).
    #[test]
    fn event_window_snapshot_between_watermark_and_firing_is_exactly_once() {
        let mk = || {
            EventWindowExec::new(
                id_ts(),
                WindowAssigner::Tumbling { size_ms: 10 },
                WindowAgg::Count,
                10,
            )
        };
        let mut w1 = mk();
        let mut out = Vec::new();
        w1.process(vec![keyed(0, 4)].into(), &mut out);
        // watermark 12 passed the window end (10) but not end+lateness
        // (20): the pane is pending, primed to fire later
        w1.on_watermark(12, &mut out);
        assert!(out.is_empty());
        let snap = w1.snapshot().expect("pending pane held");
        let mut w2 = mk();
        w2.restore(snap);
        // replay of the pre-checkpoint record (at-least-once input):
        // ts=4's window has NOT fired yet, so it re-joins the pane...
        w2.process(vec![keyed(0, 4)].into(), &mut out);
        // ...which is why the coordinator replays from the same epoch the
        // snapshot was cut at — the restored buffer already holds it; the
        // duplicate is the replay mechanism's concern, not the clock's.
        // What the clock must guarantee: no firing before 20, one at 20.
        w2.on_watermark(19, &mut out);
        assert!(out.is_empty(), "restored clock kept the pane pending");
        w2.on_watermark(20, &mut out);
        assert_eq!(out.len(), 1, "exactly one firing after restore");

        // and the restored clock also keeps classifying lateness: a
        // record below wm - lateness would have fired pre-checkpoint
        let snap2 = {
            let mut w = mk();
            w.on_watermark(40, &mut Vec::new());
            w.snapshot().expect("clock-only snapshot")
        };
        let mut w3 = mk();
        w3.restore(snap2);
        let mut late_out = Vec::new();
        w3.process(vec![keyed(0, 4)].into(), &mut late_out);
        w3.flush(&mut late_out);
        assert!(
            late_out.is_empty(),
            "window [0,10) fired before the checkpoint; restore must not re-fire it"
        );
    }

    #[test]
    fn side_tag_wraps_payload_and_keeps_routing_hash() {
        let mut t = SideTagExec(1);
        let mut out = Vec::new();
        let mut hashes = Vec::new();
        t.process_hashed(vec![keyed(3, 7)].into(), &mut out, &mut hashes);
        assert_eq!(
            out,
            vec![Value::pair(
                Value::I64(3),
                Value::pair(Value::I64(1), Value::I64(7)),
            )]
        );
        assert_eq!(hashes, vec![crate::channels::route_hash(&out[0])]);
        assert_eq!(
            hashes[0],
            crate::channels::route_hash(&keyed(3, 7)),
            "tagging must not change where the key routes"
        );
    }

    fn tagged(k: i64, side: i64, t: i64) -> Value {
        Value::pair(
            Value::I64(k),
            Value::pair(Value::I64(side), Value::I64(t)),
        )
    }

    #[test]
    fn interval_join_matches_within_bounds_exactly_once() {
        let mut j = IntervalJoinExec::new(id_ts(), id_ts(), -5, 5);
        let mut out = Vec::new();
        j.process(vec![tagged(1, 0, 100)].into(), &mut out);
        assert!(out.is_empty(), "no right side yet");
        // rights at 104 (in [95, 105]) and 110 (outside)
        j.process(vec![tagged(1, 1, 104), tagged(1, 1, 110)].into(), &mut out);
        assert_eq!(
            out,
            vec![Value::pair(
                Value::I64(1),
                Value::pair(Value::I64(100), Value::I64(104)),
            )]
        );
        out.clear();
        // a second left at 108 matches both buffered rights ([103, 113]):
        // each pair emitted exactly once, by the later arrival
        j.process(vec![tagged(1, 0, 108)].into(), &mut out);
        assert_eq!(
            out,
            vec![
                Value::pair(
                    Value::I64(1),
                    Value::pair(Value::I64(108), Value::I64(104)),
                ),
                Value::pair(
                    Value::I64(1),
                    Value::pair(Value::I64(108), Value::I64(110)),
                ),
            ]
        );
        // different key never matches
        out.clear();
        j.process(vec![tagged(2, 1, 100)].into(), &mut out);
        assert!(out.is_empty());
    }

    #[test]
    fn interval_join_evicts_on_watermark_and_counts_late() {
        let m = crate::metrics::MetricsRegistry::new();
        let mut j = IntervalJoinExec::new(id_ts(), id_ts(), 0, 10).with_metrics(m.clone());
        let mut out = Vec::new();
        j.process(vec![tagged(1, 0, 100)].into(), &mut out);
        // left at 100 matches rights in [100, 110]; watermark 111 proves
        // no such right can still arrive — evicted
        j.on_watermark(111, &mut out);
        j.process(vec![tagged(1, 1, 105)].into(), &mut out);
        assert!(out.is_empty(), "matching right arrived after eviction");
        assert_eq!(
            m.late_records.load(std::sync::atomic::Ordering::Relaxed),
            1,
            "the dead-on-arrival right is counted, not silently lost"
        );
    }

    #[test]
    fn interval_join_snapshot_restore_keeps_buffers_and_clock() {
        let mut j1 = IntervalJoinExec::new(id_ts(), id_ts(), -5, 5);
        let mut out = Vec::new();
        j1.process(vec![tagged(1, 0, 100)].into(), &mut out);
        j1.on_watermark(90, &mut out);
        let snap = j1.snapshot().expect("buffered left held");
        let mut j2 = IntervalJoinExec::new(id_ts(), id_ts(), -5, 5);
        j2.restore(snap);
        assert_eq!(j2.wm, 90, "clock restored");
        j2.process(vec![tagged(1, 1, 103)].into(), &mut out);
        assert_eq!(
            out,
            vec![Value::pair(
                Value::I64(1),
                Value::pair(Value::I64(100), Value::I64(103)),
            )]
        );
    }

    #[test]
    fn advance_chain_watermark_feeds_fired_panes_downstream() {
        // event window -> map: panes fired by the watermark must pass
        // through the map before the chain forwards the watermark
        let mut ops = chain_of(vec![
            Box::new(EventWindowExec::new(
                id_ts(),
                WindowAssigner::Tumbling { size_ms: 10 },
                WindowAgg::Count,
                0,
            )),
            Box::new(MapExec(Arc::new(|v: Value| {
                let (_, c) = v.into_pair().unwrap();
                c
            }))),
        ]);
        let mut out = Vec::new();
        ops[0].process(vec![keyed(0, 5)].into(), &mut out);
        let fwd = advance_chain_watermark(&mut ops, 0, 10, &mut out);
        assert_eq!(fwd, Some(10));
        assert_eq!(out, vec![Value::I64(1)]);
    }

    #[test]
    fn drain_generated_watermarks_cascades_from_assigner() {
        // assigner (bound 0) -> event window: the assigner's post-batch
        // watermark must fire the window's due pane in the same drain
        let mut ops = chain_of(vec![
            Box::new(AssignTsExec::new(
                id_ts(),
                WatermarkGen::BoundedOutOfOrderness { bound_ms: 0 },
            )),
            Box::new(EventWindowExec::new(
                id_ts(),
                WindowAssigner::Tumbling { size_ms: 10 },
                WindowAgg::Count,
                0,
            )),
        ]);
        let mut bufs = ChainBuffers::new(None);
        // unkeyed records: the window falls back to the Null key
        let first = run_chain(
            &mut ops,
            vec![Value::I64(3), Value::I64(7)].into(),
            &mut bufs,
        );
        assert!(first.is_empty(), "window buffers the pane");
        let mut out = Vec::new();
        assert_eq!(drain_generated_watermarks(&mut ops, &mut out), Some(7));
        assert!(out.is_empty(), "watermark 7 does not close [0,10)");
        run_chain(&mut ops, vec![Value::I64(12)].into(), &mut bufs);
        assert_eq!(drain_generated_watermarks(&mut ops, &mut out), Some(12));
        assert_eq!(out, vec![Value::pair(Value::Null, Value::I64(2))]);
    }

    #[test]
    fn flush_chain_cascades_through_downstream_ops() {
        // fold -> map: the fold's flushed pairs must pass through the map
        let mut ops = chain_of(vec![
            Box::new(FoldExec::new(
                Value::I64(0),
                Arc::new(|acc: &mut Value, _| {
                    *acc = Value::I64(acc.as_i64().unwrap() + 1);
                }),
            )),
            Box::new(MapExec(Arc::new(|v: Value| {
                let (_, c) = v.into_pair().unwrap();
                c
            }))),
        ]);
        run(&mut ops, vec![Value::I64(7), Value::I64(7)].into());
        let out = flush_chain(&mut ops);
        assert_eq!(out, vec![Value::I64(2)]);
    }
}
