//! PJRT runtime: loads AOT-compiled HLO-text artifacts (produced once at
//! build time by `python/compile/aot.py`) and executes them from the
//! streaming hot path. Python never runs at request time.
//!
//! The interchange format is HLO *text*, not a serialized `HloModuleProto`:
//! jax ≥ 0.5 emits protos with 64-bit instruction ids that the pinned
//! xla_extension (0.5.1) rejects; the text parser reassigns ids and
//! round-trips cleanly (see `/opt/xla-example/README.md`).
//!
//! The engine is gated behind the off-by-default `xla` cargo feature so
//! the crate builds as pure std on machines without the PJRT toolchain.
//! Without the feature, [`XlaEngine::global`] returns a clean error at
//! deploy time, before any worker thread spawns; the rest of the engine
//! is unaffected.

pub use engine::{Artifact, XlaEngine};

#[cfg(feature = "xla")]
mod engine {
    use crate::error::{Error, Result};
    use std::collections::HashMap;
    use std::path::{Path, PathBuf};
    use std::sync::{Arc, Mutex, OnceLock};

    /// `PjRtLoadedExecutable` holds raw pointers and is not `Send`; PJRT
    /// executables are internally thread-safe for execution, so we wrap it
    /// and serialise calls through the [`Artifact`] mutex anyway.
    struct SendExec(xla::PjRtLoadedExecutable);
    // SAFETY: execution is guarded by `Artifact::exec`'s Mutex; the
    // underlying PJRT CPU client supports invocation from any thread.
    unsafe impl Send for SendExec {}

    /// A compiled artifact ready for execution.
    pub struct Artifact {
        /// Artifact name (file stem).
        pub name: String,
        exec: Mutex<SendExec>,
    }

    impl Artifact {
        /// Executes the artifact on a row-major `f32[batch, in_dim]` buffer
        /// and returns the flattened `f32` output (row-major
        /// `[batch, out_dim]`).
        pub fn execute_f32(&self, rows: &[f32], batch: usize, in_dim: usize) -> Result<Vec<f32>> {
            if rows.len() != batch * in_dim {
                return Err(Error::Xla(format!(
                    "input length {} != batch {batch} × in_dim {in_dim}",
                    rows.len()
                )));
            }
            let input = xla::Literal::vec1(rows).reshape(&[batch as i64, in_dim as i64])?;
            let guard = self.exec.lock().unwrap();
            let result = guard.0.execute::<xla::Literal>(&[input])?[0][0].to_literal_sync()?;
            drop(guard);
            // aot.py lowers with return_tuple=True → 1-tuple
            let out = result.to_tuple1()?;
            Ok(out.to_vec::<f32>()?)
        }
    }

    /// Process-wide PJRT engine: one CPU client plus a cache of compiled
    /// artifacts keyed by name.
    pub struct XlaEngine {
        client: xla::PjRtClient,
        dir: PathBuf,
        cache: Mutex<HashMap<String, Arc<Artifact>>>,
    }

    // SAFETY: all uses of the client go through `compile` behind the cache
    // mutex; the PJRT CPU client is thread-safe.
    unsafe impl Send for XlaEngine {}
    unsafe impl Sync for XlaEngine {}

    static ENGINE: OnceLock<std::result::Result<XlaEngine, String>> = OnceLock::new();

    impl XlaEngine {
        /// Returns the process-wide engine, creating the PJRT CPU client on
        /// first use. The artifacts directory is `$FLOWUNITS_ARTIFACTS` or
        /// `./artifacts`.
        pub fn global() -> Result<&'static XlaEngine> {
            let r = ENGINE.get_or_init(|| {
                let dir =
                    std::env::var("FLOWUNITS_ARTIFACTS").unwrap_or_else(|_| "artifacts".into());
                match xla::PjRtClient::cpu() {
                    Ok(client) => Ok(XlaEngine {
                        client,
                        dir: PathBuf::from(dir),
                        cache: Mutex::new(HashMap::new()),
                    }),
                    Err(e) => Err(format!("PJRT CPU client init failed: {e}")),
                }
            });
            r.as_ref().map_err(|e| Error::Xla(e.clone()))
        }

        /// Loads (or returns the cached) artifact `name`, resolved as
        /// `<artifacts_dir>/<name>.hlo.txt`.
        pub fn load(&self, name: &str) -> Result<Arc<Artifact>> {
            {
                let cache = self.cache.lock().unwrap();
                if let Some(a) = cache.get(name) {
                    return Ok(a.clone());
                }
            }
            let path = self.dir.join(format!("{name}.hlo.txt"));
            let artifact = Arc::new(self.compile_file(name, &path)?);
            self.cache
                .lock()
                .unwrap()
                .insert(name.to_string(), artifact.clone());
            Ok(artifact)
        }

        /// Compiles an HLO text file into an executable artifact.
        pub fn compile_file(&self, name: &str, path: &Path) -> Result<Artifact> {
            if !path.exists() {
                return Err(Error::Xla(format!(
                    "artifact '{}' not found at {} — run `make artifacts` first",
                    name,
                    path.display()
                )));
            }
            let proto = xla::HloModuleProto::from_text_file(path)?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = self.client.compile(&comp)?;
            Ok(Artifact {
                name: name.to_string(),
                exec: Mutex::new(SendExec(exe)),
            })
        }

        /// Number of artifacts currently cached.
        pub fn cached(&self) -> usize {
            self.cache.lock().unwrap().len()
        }

        /// Drops a cached artifact (used by dynamic updates to force a
        /// reload after the artifact file changed).
        pub fn evict(&self, name: &str) {
            self.cache.lock().unwrap().remove(name);
        }
    }
}

#[cfg(not(feature = "xla"))]
mod engine {
    use crate::error::{Error, Result};
    use std::path::Path;
    use std::sync::Arc;

    const DISABLED: &str = "xla runtime disabled: this build omits the `xla` feature — \
         add the `xla` crate under [dependencies] in rust/Cargo.toml, rebuild with \
         `--features xla`, and run `make artifacts` to enable AOT-compiled \
         inference operators";

    /// Stub artifact (the `xla` feature is disabled; never constructed).
    pub struct Artifact {
        /// Artifact name (file stem).
        pub name: String,
    }

    impl Artifact {
        /// Always errors: the `xla` feature is disabled.
        pub fn execute_f32(
            &self,
            _rows: &[f32],
            _batch: usize,
            _in_dim: usize,
        ) -> Result<Vec<f32>> {
            Err(Error::Xla(DISABLED.into()))
        }
    }

    /// Stub engine: every entry point reports that the `xla` feature is
    /// disabled, so `xla_map` pipelines fail cleanly at deploy time.
    pub struct XlaEngine {}

    impl XlaEngine {
        /// Always errors: the `xla` feature is disabled.
        pub fn global() -> Result<&'static XlaEngine> {
            Err(Error::Xla(DISABLED.into()))
        }

        /// Always errors: the `xla` feature is disabled.
        pub fn load(&self, _name: &str) -> Result<Arc<Artifact>> {
            Err(Error::Xla(DISABLED.into()))
        }

        /// Always errors: the `xla` feature is disabled.
        pub fn compile_file(&self, _name: &str, _path: &Path) -> Result<Artifact> {
            Err(Error::Xla(DISABLED.into()))
        }

        /// Always zero: nothing can be cached without the `xla` feature.
        pub fn cached(&self) -> usize {
            0
        }

        /// No-op without the `xla` feature.
        pub fn evict(&self, _name: &str) {}
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    // Artifact-dependent tests live in `rust/tests/` (integration) because
    // they need `make artifacts` to have run. Here we only verify error
    // paths that need no artifacts.
    #[test]
    fn missing_artifact_is_a_clean_error() {
        let engine = match XlaEngine::global() {
            Ok(e) => e,
            Err(_) => return, // PJRT or the xla feature unavailable: skip
        };
        let err = match engine.load("definitely-not-an-artifact") {
            Ok(_) => panic!("expected missing-artifact error"),
            Err(e) => e,
        };
        assert!(err.to_string().contains("make artifacts"));
    }
}
