//! Monomorphized columnar executors: the typed fast path of the
//! operator runtime.
//!
//! Each executor here is the columnar twin of one `Value` executor in
//! [`exec`](super::exec), generic over the concrete `StreamData` types
//! the typed API chain was built with. [`OpExec::process_columns`]
//! iterates native column slices directly — no per-record `Value`
//! allocation, no enum-tag dispatch in the loop body — and produces
//! either a new [`ColumnBatch`] (the chain stays columnar) or `Value`
//! rows (aggregates without a static layout).
//!
//! Every executor also implements the row-path [`OpExec::process`] with
//! the same semantics as the typed layer's `Value` lowering (decode
//! failures are recorded on the shared [`DecodeErrors`] accumulator and
//! the event is dropped), so a columnar operator that receives a row
//! batch — a mixed chain, a replayed queue segment, a restored
//! snapshot — behaves identically to the classic pipeline. A columnar
//! batch whose [`Layout`] is not the one the executor was compiled for
//! is handed back as [`ColumnFlow::Fallback`] and the chain continues on
//! materialized rows: never wrong, merely slower.
//!
//! Keyed state (`fold`/`reduce`/`window`) is keyed by the canonical
//! encoded key bytes — [`Layout::encode_row`] over the key sub-columns
//! produces exactly [`Value::encode_into`] of the materialized key — so
//! state maps, flush order, and snapshot/restore payloads are
//! byte-compatible with the `Value` executors; a dynamic update may hand
//! state across the representation boundary in either direction.

use super::exec::{ChainInput, ColumnFlow, EventWindowExec, FnvMap, OpExec, WindowExec};
use crate::api::data::DecodeErrors;
use crate::columnar::{ColumnBatch, Layout};
use crate::graph::WindowAgg;
use crate::time::{WatermarkGen, WatermarkState};
use crate::value::{StreamData, Value};
use std::marker::PhantomData;
use std::sync::Arc;

/// Decodes a dynamic value on the row path, recording (and dropping)
/// mismatches exactly like the typed layer's `Value` lowering shims.
fn decode<T: StreamData>(errs: &DecodeErrors, op: &'static str, v: Value) -> Option<T> {
    match T::try_from_value(v) {
        Ok(t) => Some(t),
        Err(e) => {
            errs.record(op, &e);
            None
        }
    }
}

/// Typed `map`: `T -> U` over native columns.
pub struct ColumnMapExec<T: StreamData, U: StreamData> {
    f: Arc<dyn Fn(T) -> U + Send + Sync>,
    errs: Arc<DecodeErrors>,
    in_layout: Layout,
    out_layout: Layout,
}

impl<T: StreamData, U: StreamData> ColumnMapExec<T, U> {
    /// Creates the executor; both `T` and `U` must be columnar types.
    pub fn new(f: Arc<dyn Fn(T) -> U + Send + Sync>, errs: Arc<DecodeErrors>) -> Self {
        ColumnMapExec {
            f,
            errs,
            in_layout: T::layout().expect("columnar map input"),
            out_layout: U::layout().expect("columnar map output"),
        }
    }
}

impl<T: StreamData, U: StreamData> OpExec for ColumnMapExec<T, U> {
    fn process(&mut self, input: ChainInput<'_>, out: &mut Vec<Value>) {
        for v in input.drain() {
            if let Some(t) = decode::<T>(&self.errs, "map", v) {
                out.push((self.f)(t).into_value());
            }
        }
    }

    fn process_columns(&mut self, input: ColumnBatch) -> ColumnFlow {
        if input.layout() != &self.in_layout {
            return ColumnFlow::Fallback(input);
        }
        let cols = input.columns();
        let mut out = self.out_layout.new_columns(input.len());
        for row in 0..input.len() {
            (self.f)(T::read_columns(cols, row)).append_columns(&mut out);
        }
        ColumnFlow::Columns(ColumnBatch::new(self.out_layout.clone(), out))
    }
}

/// Typed `filter`: kept rows are copied column-wise; an attached
/// routing-hash column survives (rows are unchanged, so their hashes
/// stay valid).
pub struct ColumnFilterExec<T: StreamData> {
    f: Arc<dyn Fn(&T) -> bool + Send + Sync>,
    errs: Arc<DecodeErrors>,
    layout: Layout,
}

impl<T: StreamData> ColumnFilterExec<T> {
    /// Creates the executor; `T` must be a columnar type.
    pub fn new(f: Arc<dyn Fn(&T) -> bool + Send + Sync>, errs: Arc<DecodeErrors>) -> Self {
        ColumnFilterExec {
            f,
            errs,
            layout: T::layout().expect("columnar filter input"),
        }
    }
}

impl<T: StreamData> OpExec for ColumnFilterExec<T> {
    fn process(&mut self, input: ChainInput<'_>, out: &mut Vec<Value>) {
        for v in input.drain() {
            if let Some(t) = decode::<T>(&self.errs, "filter", v) {
                if (self.f)(&t) {
                    out.push(t.into_value());
                }
            }
        }
    }

    fn process_columns(&mut self, input: ColumnBatch) -> ColumnFlow {
        if input.layout() != &self.layout {
            return ColumnFlow::Fallback(input);
        }
        let cols = input.columns();
        let src_hashes = input.key_hashes();
        let mut out = self.layout.new_columns(input.len());
        let mut kept = src_hashes.map(|_| Vec::new());
        for row in 0..input.len() {
            if (self.f)(&T::read_columns(cols, row)) {
                for (dst, src) in out.iter_mut().zip(cols) {
                    dst.push_from(src, row);
                }
                if let (Some(kept), Some(hs)) = (kept.as_mut(), src_hashes) {
                    kept.push(hs[row]);
                }
            }
        }
        let cb = match kept {
            Some(hs) => ColumnBatch::with_hashes(self.layout.clone(), out, hs),
            None => ColumnBatch::new(self.layout.clone(), out),
        };
        ColumnFlow::Columns(cb)
    }
}

/// Typed `filter_map`: `T -> Option<U>` in one columnar pass.
pub struct ColumnFilterMapExec<T: StreamData, U: StreamData> {
    f: Arc<dyn Fn(T) -> Option<U> + Send + Sync>,
    errs: Arc<DecodeErrors>,
    in_layout: Layout,
    out_layout: Layout,
}

impl<T: StreamData, U: StreamData> ColumnFilterMapExec<T, U> {
    /// Creates the executor; both `T` and `U` must be columnar types.
    pub fn new(f: Arc<dyn Fn(T) -> Option<U> + Send + Sync>, errs: Arc<DecodeErrors>) -> Self {
        ColumnFilterMapExec {
            f,
            errs,
            in_layout: T::layout().expect("columnar filter_map input"),
            out_layout: U::layout().expect("columnar filter_map output"),
        }
    }
}

impl<T: StreamData, U: StreamData> OpExec for ColumnFilterMapExec<T, U> {
    fn process(&mut self, input: ChainInput<'_>, out: &mut Vec<Value>) {
        for v in input.drain() {
            if let Some(t) = decode::<T>(&self.errs, "filter_map", v) {
                if let Some(u) = (self.f)(t) {
                    out.push(u.into_value());
                }
            }
        }
    }

    fn process_columns(&mut self, input: ColumnBatch) -> ColumnFlow {
        if input.layout() != &self.in_layout {
            return ColumnFlow::Fallback(input);
        }
        let cols = input.columns();
        let mut out = self.out_layout.new_columns(input.len());
        for row in 0..input.len() {
            if let Some(u) = (self.f)(T::read_columns(cols, row)) {
                u.append_columns(&mut out);
            }
        }
        ColumnFlow::Columns(ColumnBatch::new(self.out_layout.clone(), out))
    }
}

/// Typed `key_by`: emits the keyed `Pair(K, T)` layout and fills the
/// computed routing-hash column ([`ColumnBatch::key_hashes`]) with the
/// key's [`Value::stable_hash`] — downstream hash shuffles read one
/// `u64` per row instead of re-walking the record.
pub struct ColumnKeyByExec<T: StreamData, K: StreamData> {
    f: Arc<dyn Fn(&T) -> K + Send + Sync>,
    errs: Arc<DecodeErrors>,
    in_layout: Layout,
    out_layout: Layout,
    key_layout: Layout,
    key_leaves: usize,
}

impl<T: StreamData, K: StreamData> ColumnKeyByExec<T, K> {
    /// Creates the executor; both `T` and `K` must be columnar types.
    pub fn new(f: Arc<dyn Fn(&T) -> K + Send + Sync>, errs: Arc<DecodeErrors>) -> Self {
        let key_layout = K::layout().expect("columnar key type");
        let in_layout = T::layout().expect("columnar key_by input");
        ColumnKeyByExec {
            f,
            errs,
            out_layout: Layout::pair(key_layout.clone(), in_layout.clone()),
            in_layout,
            key_layout,
            key_leaves: K::column_count(),
        }
    }
}

impl<T: StreamData, K: StreamData> OpExec for ColumnKeyByExec<T, K> {
    fn process(&mut self, input: ChainInput<'_>, out: &mut Vec<Value>) {
        for v in input.drain() {
            if let Some(t) = decode::<T>(&self.errs, "key_by", v) {
                let k = (self.f)(&t);
                out.push(Value::pair(k.into_value(), t.into_value()));
            }
        }
    }

    fn process_hashed(
        &mut self,
        input: ChainInput<'_>,
        out: &mut Vec<Value>,
        hashes: &mut Vec<u64>,
    ) {
        for v in input.drain() {
            if let Some(t) = decode::<T>(&self.errs, "key_by", v) {
                let kv = (self.f)(&t).into_value();
                hashes.push(kv.stable_hash());
                out.push(Value::pair(kv, t.into_value()));
            }
        }
    }

    fn process_columns(&mut self, input: ColumnBatch) -> ColumnFlow {
        if input.layout() != &self.in_layout {
            return ColumnFlow::Fallback(input);
        }
        let cols = input.columns();
        let n = input.len();
        let kc = self.key_leaves;
        let mut out = self.out_layout.new_columns(n);
        let mut hashes = Vec::with_capacity(n);
        for row in 0..n {
            let t = T::read_columns(cols, row);
            let k = (self.f)(&t);
            k.append_columns(&mut out[..kc]);
            t.append_columns(&mut out[kc..]);
            hashes.push(self.key_layout.hash_row(&out[..kc], row));
        }
        ColumnFlow::Columns(ColumnBatch::with_hashes(self.out_layout.clone(), out, hashes))
    }
}

/// Typed keyed `fold`: a native `A` accumulator per key, stepped without
/// any per-event `Value` round-trip on the columnar path. State and
/// snapshot format are byte-compatible with
/// [`FoldExec`](super::exec::FoldExec).
pub struct ColumnFoldExec<K: StreamData, V: StreamData, A: StreamData> {
    init: Value,
    step: Arc<dyn Fn(&mut A, V) + Send + Sync>,
    errs: Arc<DecodeErrors>,
    in_layout: Layout,
    key_layout: Layout,
    key_leaves: usize,
    /// encoded key → (key, accumulator).
    state: FnvMap<(Value, A)>,
    scratch: Vec<u8>,
    _k: PhantomData<K>,
}

impl<K: StreamData, V: StreamData, A: StreamData> ColumnFoldExec<K, V, A> {
    /// Creates the executor; `K` and `V` must be columnar types.
    pub fn new(init: A, step: Arc<dyn Fn(&mut A, V) + Send + Sync>, errs: Arc<DecodeErrors>) -> Self {
        Self::from_init_value(init.into_value(), step, errs)
    }

    /// Like [`ColumnFoldExec::new`], but takes the initial accumulator
    /// already lowered to a `Value` — the typed layer's operator factory
    /// is called once per stage instance, so it holds the init in the
    /// clonable `Value` form rather than requiring `A: Clone`.
    pub fn from_init_value(
        init: Value,
        step: Arc<dyn Fn(&mut A, V) + Send + Sync>,
        errs: Arc<DecodeErrors>,
    ) -> Self {
        let key_layout = K::layout().expect("columnar fold key");
        let value_layout = V::layout().expect("columnar fold input");
        ColumnFoldExec {
            init,
            step,
            errs,
            in_layout: Layout::pair(key_layout.clone(), value_layout),
            key_layout,
            key_leaves: K::column_count(),
            state: FnvMap::default(),
            scratch: Vec::with_capacity(32),
            _k: PhantomData,
        }
    }

    fn fold_in(&mut self, key_value: impl FnOnce() -> Value, payload: V) {
        match self.state.get_mut(self.scratch.as_slice()) {
            Some(entry) => (self.step)(&mut entry.1, payload),
            None => {
                let mut acc = match A::try_from_value(self.init.clone()) {
                    Ok(a) => a,
                    Err(e) => {
                        self.errs.record("fold", &e);
                        return;
                    }
                };
                (self.step)(&mut acc, payload);
                self.state.insert(self.scratch.clone(), (key_value(), acc));
            }
        }
    }
}

impl<K: StreamData, V: StreamData, A: StreamData> OpExec for ColumnFoldExec<K, V, A> {
    fn process(&mut self, input: ChainInput<'_>, _out: &mut Vec<Value>) {
        for v in input.drain() {
            let (key, payload) = match v {
                Value::Pair(kp) => (kp.0, kp.1),
                other => (Value::Null, other),
            };
            let Some(pv) = decode::<V>(&self.errs, "fold", payload) else {
                continue;
            };
            self.scratch.clear();
            key.encode_into(&mut self.scratch);
            self.fold_in(|| key, pv);
        }
    }

    fn process_columns(&mut self, input: ColumnBatch) -> ColumnFlow {
        if input.layout() != &self.in_layout {
            return ColumnFlow::Fallback(input);
        }
        let kc = self.key_leaves;
        for row in 0..input.len() {
            let cols = input.columns();
            let payload = V::read_columns(&cols[kc..], row);
            self.scratch.clear();
            self.key_layout.encode_row(&cols[..kc], row, &mut self.scratch);
            let key_layout = &self.key_layout;
            match self.state.get_mut(self.scratch.as_slice()) {
                Some(entry) => (self.step)(&mut entry.1, payload),
                None => {
                    let mut acc = match A::try_from_value(self.init.clone()) {
                        Ok(a) => a,
                        Err(e) => {
                            self.errs.record("fold", &e);
                            continue;
                        }
                    };
                    (self.step)(&mut acc, payload);
                    let key = key_layout.read_value(&cols[..kc], row);
                    self.state.insert(self.scratch.clone(), (key, acc));
                }
            }
        }
        ColumnFlow::Rows(Vec::new())
    }

    fn flush(&mut self, out: &mut Vec<Value>) {
        // deterministic emission order despite the hash map
        let mut entries: Vec<(Vec<u8>, (Value, A))> = self.state.drain().collect();
        entries.sort_by(|a, b| a.0.cmp(&b.0));
        for (_, (key, acc)) in entries {
            out.push(Value::pair(key, acc.into_value()));
        }
    }

    fn snapshot(&mut self) -> Option<Value> {
        if self.state.is_empty() {
            return None;
        }
        let mut entries: Vec<(Vec<u8>, (Value, A))> = self.state.drain().collect();
        entries.sort_by(|a, b| a.0.cmp(&b.0));
        Some(Value::List(
            entries
                .into_iter()
                .map(|(_, (key, acc))| Value::pair(key, acc.into_value()))
                .collect(),
        ))
    }

    fn restore(&mut self, state: Value) {
        let Value::List(entries) = state else { return };
        for e in entries {
            let Some((key, acc)) = e.into_pair() else { continue };
            let Some(acc) = decode::<A>(&self.errs, "fold", acc) else {
                continue;
            };
            self.scratch.clear();
            key.encode_into(&mut self.scratch);
            // a key restored twice keeps the first accumulator, matching
            // FoldExec: fold partials are not mergeable
            if !self.state.contains_key(self.scratch.as_slice()) {
                self.state.insert(self.scratch.clone(), (key, acc));
            }
        }
    }
}

/// Typed keyed `reduce`: native `V` accumulators with an explicit empty
/// state, byte-compatible with [`ReduceExec`](super::exec::ReduceExec).
pub struct ColumnReduceExec<K: StreamData, V: StreamData> {
    f: Arc<dyn Fn(&V, &V) -> V + Send + Sync>,
    errs: Arc<DecodeErrors>,
    in_layout: Layout,
    key_layout: Layout,
    key_leaves: usize,
    /// encoded key → (key, accumulator-if-any).
    state: FnvMap<(Value, Option<V>)>,
    scratch: Vec<u8>,
    _k: PhantomData<K>,
}

impl<K: StreamData, V: StreamData> ColumnReduceExec<K, V> {
    /// Creates the executor; `K` and `V` must be columnar types.
    pub fn new(f: Arc<dyn Fn(&V, &V) -> V + Send + Sync>, errs: Arc<DecodeErrors>) -> Self {
        let key_layout = K::layout().expect("columnar reduce key");
        let value_layout = V::layout().expect("columnar reduce input");
        ColumnReduceExec {
            f,
            errs,
            in_layout: Layout::pair(key_layout.clone(), value_layout),
            key_layout,
            key_leaves: K::column_count(),
            state: FnvMap::default(),
            scratch: Vec::with_capacity(32),
            _k: PhantomData,
        }
    }

    /// Merges `payload` into the state slot keyed by `self.scratch`.
    fn reduce_in(&mut self, key_value: impl FnOnce() -> Value, payload: V) {
        match self.state.get_mut(self.scratch.as_slice()) {
            Some(entry) => {
                entry.1 = Some(match entry.1.take() {
                    Some(prev) => (self.f)(&prev, &payload),
                    None => payload,
                });
            }
            None => {
                self.state
                    .insert(self.scratch.clone(), (key_value(), Some(payload)));
            }
        }
    }
}

impl<K: StreamData, V: StreamData> OpExec for ColumnReduceExec<K, V> {
    fn process(&mut self, input: ChainInput<'_>, _out: &mut Vec<Value>) {
        for v in input.drain() {
            let (key, payload) = match v {
                Value::Pair(kp) => (kp.0, kp.1),
                other => (Value::Null, other),
            };
            let Some(pv) = decode::<V>(&self.errs, "reduce", payload) else {
                continue;
            };
            self.scratch.clear();
            key.encode_into(&mut self.scratch);
            self.reduce_in(|| key, pv);
        }
    }

    fn process_columns(&mut self, input: ColumnBatch) -> ColumnFlow {
        if input.layout() != &self.in_layout {
            return ColumnFlow::Fallback(input);
        }
        let kc = self.key_leaves;
        let key_layout = self.key_layout.clone();
        for row in 0..input.len() {
            let cols = input.columns();
            let payload = V::read_columns(&cols[kc..], row);
            self.scratch.clear();
            key_layout.encode_row(&cols[..kc], row, &mut self.scratch);
            self.reduce_in(|| key_layout.read_value(&input.columns()[..kc], row), payload);
        }
        ColumnFlow::Rows(Vec::new())
    }

    fn flush(&mut self, out: &mut Vec<Value>) {
        // deterministic emission order despite the hash map
        let mut entries: Vec<(Vec<u8>, (Value, Option<V>))> = self.state.drain().collect();
        entries.sort_by(|a, b| a.0.cmp(&b.0));
        for (_, (key, acc)) in entries {
            if let Some(acc) = acc {
                out.push(Value::pair(key, acc.into_value()));
            }
        }
    }

    fn snapshot(&mut self) -> Option<Value> {
        if self.state.is_empty() {
            return None;
        }
        let mut entries: Vec<(Vec<u8>, (Value, Option<V>))> = self.state.drain().collect();
        entries.sort_by(|a, b| a.0.cmp(&b.0));
        let list: Vec<Value> = entries
            .into_iter()
            .filter_map(|(_, (key, acc))| acc.map(|a| Value::pair(key, a.into_value())))
            .collect();
        if list.is_empty() {
            None
        } else {
            Some(Value::List(list))
        }
    }

    fn restore(&mut self, state: Value) {
        let Value::List(entries) = state else { return };
        for e in entries {
            let Some((key, acc)) = e.into_pair() else { continue };
            let Some(acc) = decode::<V>(&self.errs, "reduce", acc) else {
                continue;
            };
            self.scratch.clear();
            key.encode_into(&mut self.scratch);
            // a key restored twice combines through the reduction itself,
            // matching ReduceExec
            self.reduce_in(|| key, acc);
        }
    }
}

/// Count-based (sliding) window over a keyed columnar stream. Ingestion
/// runs columnar — key bytes come straight off the key sub-columns —
/// while the per-key buffers and emitted `Pair(key, aggregate)` rows stay
/// dynamic (aggregates have no static layout), so the chain switches to
/// rows at the window. State and snapshot format are byte-compatible
/// with [`WindowExec`](super::exec::WindowExec).
pub struct ColumnWindowExec {
    size: usize,
    slide: usize,
    agg: WindowAgg,
    in_layout: Layout,
    key_layout: Layout,
    value_layout: Layout,
    key_leaves: usize,
    state: FnvMap<(Value, Vec<Value>)>,
    scratch: Vec<u8>,
}

impl ColumnWindowExec {
    /// Creates a window executor for a keyed stream of layout
    /// `Pair(key_layout, value_layout)`.
    pub fn new(
        size: usize,
        slide: usize,
        agg: WindowAgg,
        key_layout: Layout,
        value_layout: Layout,
    ) -> Self {
        ColumnWindowExec {
            size,
            slide,
            agg,
            in_layout: Layout::pair(key_layout.clone(), value_layout.clone()),
            key_leaves: key_layout.leaf_count(),
            key_layout,
            value_layout,
            state: FnvMap::default(),
            scratch: Vec::with_capacity(32),
        }
    }

    /// Appends `payload` to the window keyed by `self.scratch`, emitting
    /// a full window's aggregate if one completes.
    fn window_in(&mut self, key_value: impl FnOnce() -> Value, payload: Value, out: &mut Vec<Value>) {
        if !self.state.contains_key(self.scratch.as_slice()) {
            self.state.insert(
                self.scratch.clone(),
                (key_value(), Vec::with_capacity(self.size)),
            );
        }
        let entry = self
            .state
            .get_mut(self.scratch.as_slice())
            .expect("window slot just ensured");
        entry.1.push(payload);
        if entry.1.len() >= self.size {
            let agg = WindowExec::aggregate(&self.agg, &entry.1);
            out.push(Value::pair(entry.0.clone(), agg));
            entry.1.drain(..self.slide);
        }
    }
}

impl OpExec for ColumnWindowExec {
    fn process(&mut self, input: ChainInput<'_>, out: &mut Vec<Value>) {
        for v in input.drain() {
            let (key, payload) = match v {
                Value::Pair(kp) => (kp.0, kp.1),
                other => (Value::Null, other),
            };
            self.scratch.clear();
            key.encode_into(&mut self.scratch);
            self.window_in(|| key, payload, out);
        }
    }

    fn process_columns(&mut self, input: ColumnBatch) -> ColumnFlow {
        if input.layout() != &self.in_layout {
            return ColumnFlow::Fallback(input);
        }
        let kc = self.key_leaves;
        let key_layout = self.key_layout.clone();
        let value_layout = self.value_layout.clone();
        let mut out = Vec::new();
        for row in 0..input.len() {
            let cols = input.columns();
            let payload = value_layout.read_value(&cols[kc..], row);
            self.scratch.clear();
            key_layout.encode_row(&cols[..kc], row, &mut self.scratch);
            self.window_in(
                || key_layout.read_value(&input.columns()[..kc], row),
                payload,
                &mut out,
            );
        }
        ColumnFlow::Rows(out)
    }

    fn flush(&mut self, out: &mut Vec<Value>) {
        // deterministic emission order despite the hash map
        let mut entries: Vec<(Vec<u8>, (Value, Vec<Value>))> = self.state.drain().collect();
        entries.sort_by(|a, b| a.0.cmp(&b.0));
        for (_, (key, buf)) in entries {
            if !buf.is_empty() {
                out.push(Value::pair(key, WindowExec::aggregate(&self.agg, &buf)));
            }
        }
    }

    fn snapshot(&mut self) -> Option<Value> {
        if self.state.is_empty() {
            return None;
        }
        let mut entries: Vec<(Vec<u8>, (Value, Vec<Value>))> = self.state.drain().collect();
        entries.sort_by(|a, b| a.0.cmp(&b.0));
        let list: Vec<Value> = entries
            .into_iter()
            .filter(|(_, (_, buf))| !buf.is_empty())
            .map(|(_, (key, buf))| Value::pair(key, Value::List(buf)))
            .collect();
        if list.is_empty() {
            None
        } else {
            Some(Value::List(list))
        }
    }

    fn restore(&mut self, state: Value) {
        let Value::List(entries) = state else { return };
        for e in entries {
            let Some((key, buf)) = e.into_pair() else { continue };
            let Value::List(buf) = buf else { continue };
            self.scratch.clear();
            key.encode_into(&mut self.scratch);
            if !self.state.contains_key(self.scratch.as_slice()) {
                self.state.insert(
                    self.scratch.clone(),
                    (key, Vec::with_capacity(self.size)),
                );
            }
            let entry = self
                .state
                .get_mut(self.scratch.as_slice())
                .expect("window slot just ensured");
            // a key restored twice concatenates its partial windows
            entry.1.extend(buf);
        }
    }
}

/// Typed `assign_timestamps`: extracts each row's event timestamp from
/// native columns and feeds the watermark generator, passing the batch
/// through untouched (zero-copy — timestamps are a read-only scan).
/// Snapshot format is byte-compatible with
/// [`AssignTsExec`](super::exec::AssignTsExec). On the columnar path a
/// punctuated generator has no row to test, so it degrades to per-batch
/// emission ([`WatermarkState::observe_ts`]); the row path punctuates
/// exactly.
pub struct ColumnAssignTsExec<T: StreamData> {
    ts: Arc<dyn Fn(&T) -> i64 + Send + Sync>,
    errs: Arc<DecodeErrors>,
    layout: Layout,
    state: WatermarkState,
}

impl<T: StreamData> ColumnAssignTsExec<T> {
    /// Creates the executor; `T` must be a columnar type.
    pub fn new(
        ts: Arc<dyn Fn(&T) -> i64 + Send + Sync>,
        gen: WatermarkGen,
        errs: Arc<DecodeErrors>,
    ) -> Self {
        ColumnAssignTsExec {
            ts,
            errs,
            layout: T::layout().expect("columnar assign_timestamps input"),
            state: WatermarkState::new(gen),
        }
    }
}

impl<T: StreamData> OpExec for ColumnAssignTsExec<T> {
    fn process(&mut self, input: ChainInput<'_>, out: &mut Vec<Value>) {
        for v in input.drain() {
            if let Some(t) = decode::<T>(&self.errs, "assign_timestamps", v) {
                let ts = (self.ts)(&t);
                let v = t.into_value();
                self.state.observe(&v, ts);
                out.push(v);
            }
        }
    }

    fn process_columns(&mut self, input: ColumnBatch) -> ColumnFlow {
        if input.layout() != &self.layout {
            return ColumnFlow::Fallback(input);
        }
        let cols = input.columns();
        for row in 0..input.len() {
            self.state.observe_ts((self.ts)(&T::read_columns(cols, row)));
        }
        ColumnFlow::Columns(input)
    }

    fn on_watermark(&mut self, _wm: i64, _out: &mut Vec<Value>) -> Option<i64> {
        // an assigner replaces the upstream time domain
        None
    }

    fn take_watermark(&mut self) -> Option<i64> {
        self.state.take()
    }

    fn snapshot(&mut self) -> Option<Value> {
        Some(Value::List(vec![Value::pair(
            Value::Null,
            self.state.snapshot(),
        )]))
    }

    fn restore(&mut self, state: Value) {
        let Value::List(entries) = state else { return };
        for e in entries {
            let Some((_, s)) = e.into_pair() else { continue };
            self.state.restore(&s);
        }
    }
}

/// Event-time window over a keyed columnar stream: ingestion reads key
/// and payload straight off the columns, while pane state, firing, and
/// snapshots are delegated to the wrapped [`EventWindowExec`] — the two
/// planes share one clock and one state format, so a checkpoint taken
/// under either restores into the other.
pub struct ColumnEventWindowExec {
    inner: EventWindowExec,
    in_layout: Layout,
    key_layout: Layout,
    value_layout: Layout,
    key_leaves: usize,
}

impl ColumnEventWindowExec {
    /// Wraps an event-window executor for a keyed stream of layout
    /// `Pair(key_layout, value_layout)`.
    pub fn new(inner: EventWindowExec, key_layout: Layout, value_layout: Layout) -> Self {
        ColumnEventWindowExec {
            inner,
            in_layout: Layout::pair(key_layout.clone(), value_layout.clone()),
            key_leaves: key_layout.leaf_count(),
            key_layout,
            value_layout,
        }
    }
}

impl OpExec for ColumnEventWindowExec {
    fn process(&mut self, input: ChainInput<'_>, out: &mut Vec<Value>) {
        self.inner.process(input, out);
    }

    fn process_columns(&mut self, input: ColumnBatch) -> ColumnFlow {
        if input.layout() != &self.in_layout {
            return ColumnFlow::Fallback(input);
        }
        let kc = self.key_leaves;
        let cols = input.columns();
        let mut rows = Vec::with_capacity(input.len());
        for row in 0..input.len() {
            rows.push(Value::pair(
                self.key_layout.read_value(&cols[..kc], row),
                self.value_layout.read_value(&cols[kc..], row),
            ));
        }
        let mut out = Vec::new();
        self.inner.process(rows.into(), &mut out);
        ColumnFlow::Rows(out)
    }

    fn on_watermark(&mut self, wm: i64, out: &mut Vec<Value>) -> Option<i64> {
        self.inner.on_watermark(wm, out)
    }

    fn take_watermark(&mut self) -> Option<i64> {
        self.inner.take_watermark()
    }

    fn flush(&mut self, out: &mut Vec<Value>) {
        self.inner.flush(out);
    }

    fn snapshot(&mut self) -> Option<Value> {
        self.inner.snapshot()
    }

    fn restore(&mut self, state: Value) {
        self.inner.restore(state);
    }
}

/// A convenience used by the typed lowering: builds a [`ColumnBatch`]
/// from typed items (the columnar synthetic source path).
pub fn column_batch_of<T: StreamData>(layout: &Layout, items: impl Iterator<Item = T>) -> ColumnBatch {
    let (lo, hi) = items.size_hint();
    let mut cols = layout.new_columns(hi.unwrap_or(lo));
    for item in items {
        item.append_columns(&mut cols);
    }
    ColumnBatch::new(layout.clone(), cols)
}

#[cfg(test)]
mod tests {
    use super::super::exec::{
        flush_chain, run_chain, run_chain_data, ChainBuffers, FilterExec, FoldExec, KeyByExec,
        MapExec, ReduceExec,
    };
    use super::*;
    use crate::value::{Batch, BatchData};

    fn errs() -> Arc<DecodeErrors> {
        Arc::new(DecodeErrors::default())
    }

    fn i64_batch(n: i64) -> ColumnBatch {
        column_batch_of(&Layout::I64, 0..n)
    }

    fn sorted(mut v: Vec<Value>) -> Vec<Value> {
        v.sort_by(|a, b| a.encode().cmp(&b.encode()));
        v
    }

    #[test]
    fn columnar_map_filter_key_by_matches_value_chain() {
        let cb = i64_batch(1000);
        let rows = cb.to_batch();

        let mut col_ops: Vec<Box<dyn OpExec>> = vec![
            Box::new(ColumnMapExec::<i64, i64>::new(Arc::new(|x| x * 31), errs())),
            Box::new(ColumnFilterExec::<i64>::new(Arc::new(|x| x % 7 != 0), errs())),
            Box::new(ColumnKeyByExec::<i64, i64>::new(Arc::new(|x| x % 64), errs())),
        ];
        let mut row_ops: Vec<Box<dyn OpExec>> = vec![
            Box::new(MapExec(Arc::new(|v: Value| {
                Value::I64(v.as_i64().unwrap() * 31)
            }))),
            Box::new(FilterExec(Arc::new(|v: &Value| {
                v.as_i64().unwrap() % 7 != 0
            }))),
            Box::new(KeyByExec(Arc::new(|v: &Value| {
                Value::I64(v.as_i64().unwrap() % 64)
            }))),
        ];

        let mut bufs = ChainBuffers::new(None);
        let got = match run_chain_data(&mut col_ops, BatchData::Columns(cb), &mut bufs) {
            BatchData::Columns(c) => c,
            BatchData::Rows(_) => panic!("chain should stay columnar"),
        };
        let expect = run_chain(&mut row_ops, rows, &mut bufs);

        assert_eq!(got.to_batch().values(), expect.values());
        // the computed hash column agrees with the row path's
        assert_eq!(got.key_hashes().unwrap(), expect.key_hashes().unwrap());
    }

    #[test]
    fn columnar_executors_row_path_matches_value_executors() {
        // a columnar executor fed ROW batches (mixed chain) behaves
        // exactly like the classic executor
        let rows = i64_batch(500).to_batch();
        let mut bufs = ChainBuffers::new(None);

        let mut col_op: Vec<Box<dyn OpExec>> = vec![Box::new(ColumnFilterMapExec::<i64, i64>::new(
            Arc::new(|x| if x % 2 == 0 { Some(x + 1) } else { None }),
            errs(),
        ))];
        let got = run_chain(&mut col_op, rows.clone(), &mut bufs);

        let mut row_op: Vec<Box<dyn OpExec>> = vec![Box::new(crate::runtime::exec::FilterMapExec(
            Arc::new(|v: Value| {
                let x = v.as_i64().unwrap();
                if x % 2 == 0 {
                    Some(Value::I64(x + 1))
                } else {
                    None
                }
            }),
        ))];
        let expect = run_chain(&mut row_op, rows, &mut bufs);
        assert_eq!(got.values(), expect.values());
    }

    #[test]
    fn layout_mismatch_falls_back_to_rows() {
        let cb = column_batch_of(&Layout::F64, [1.5f64, 2.5].into_iter());
        let mut op = ColumnMapExec::<i64, i64>::new(Arc::new(|x| x), errs());
        match op.process_columns(cb.clone()) {
            ColumnFlow::Fallback(same) => assert!(ColumnBatch::ptr_eq(&same, &cb)),
            _ => panic!("expected fallback on foreign layout"),
        }
    }

    #[test]
    fn columnar_fold_matches_value_fold() {
        let keyed = column_batch_of(
            &Layout::pair(Layout::I64, Layout::I64),
            (0..300i64).map(|i| (i % 5, i)),
        );

        let mut col_ops: Vec<Box<dyn OpExec>> =
            vec![Box::new(ColumnFoldExec::<i64, i64, i64>::new(
                0,
                Arc::new(|acc, x| *acc += x),
                errs(),
            ))];
        let mut row_ops: Vec<Box<dyn OpExec>> = vec![Box::new(FoldExec::new(
            Value::I64(0),
            Arc::new(|acc: &mut Value, v: Value| {
                *acc = Value::I64(acc.as_i64().unwrap() + v.as_i64().unwrap())
            }),
        ))];

        let mut bufs = ChainBuffers::new(None);
        let out = run_chain_data(&mut col_ops, BatchData::Columns(keyed.clone()), &mut bufs);
        assert!(out.is_empty(), "fold emits nothing mid-stream");
        run_chain(&mut row_ops, keyed.to_batch(), &mut bufs);

        assert_eq!(flush_chain(&mut col_ops), flush_chain(&mut row_ops));
    }

    #[test]
    fn columnar_reduce_snapshot_restores_into_value_reduce() {
        let keyed = column_batch_of(
            &Layout::pair(Layout::I64, Layout::I64),
            (0..100i64).map(|i| (i % 3, i)),
        );
        let mut col_op = ColumnReduceExec::<i64, i64>::new(Arc::new(|a, b| (*a).max(*b)), errs());
        let _ = col_op.process_columns(keyed.clone());
        let snap = col_op.snapshot().expect("state present");

        // the snapshot restores into the CLASSIC executor (dynamic-update
        // handoff across the representation boundary)
        let mut row_op = ReduceExec::new(Arc::new(|a: &Value, b: &Value| {
            Value::I64(a.as_i64().unwrap().max(b.as_i64().unwrap()))
        }));
        row_op.restore(snap);
        let mut out = Vec::new();
        row_op.flush(&mut out);
        assert_eq!(
            sorted(out),
            sorted(vec![
                Value::pair(Value::I64(0), Value::I64(99)),
                Value::pair(Value::I64(1), Value::I64(97)),
                Value::pair(Value::I64(2), Value::I64(98)),
            ])
        );
    }

    #[test]
    fn columnar_window_matches_value_window_through_flush() {
        let keyed = column_batch_of(
            &Layout::pair(Layout::I64, Layout::F64),
            (0..250i64).map(|i| (i % 4, i as f64)),
        );
        let mut col_op =
            ColumnWindowExec::new(20, 20, WindowAgg::Mean, Layout::I64, Layout::F64);
        let mut row_op = crate::runtime::exec::WindowExec::new(20, 20, WindowAgg::Mean);

        let got = match col_op.process_columns(keyed.clone()) {
            ColumnFlow::Rows(rows) => rows,
            _ => panic!("window emits rows"),
        };
        let mut expect = Vec::new();
        row_op.process(ChainInput::Shared(keyed.to_batch()), &mut expect);
        assert_eq!(got, expect);

        let mut got_tail = Vec::new();
        let mut expect_tail = Vec::new();
        col_op.flush(&mut got_tail);
        row_op.flush(&mut expect_tail);
        assert_eq!(got_tail, expect_tail);
    }

    #[test]
    fn value_reduce_snapshot_restores_into_columnar_reduce() {
        // the reverse crossing: a CLASSIC snapshot restores into the
        // columnar executor (checkpoint taken under the row plane,
        // recovered under the columnar plane), which keeps reducing
        let pairs = |r: std::ops::Range<i64>| {
            column_batch_of(&Layout::pair(Layout::I64, Layout::I64), r.map(|i| (i % 3, i)))
        };
        let mut row_op = ReduceExec::new(Arc::new(|a: &Value, b: &Value| {
            Value::I64(a.as_i64().unwrap() + b.as_i64().unwrap())
        }));
        let mut sink = Vec::new();
        row_op.process(ChainInput::Shared(pairs(0..100).to_batch()), &mut sink);
        let snap = row_op.snapshot().expect("state present");

        let mut col_op = ColumnReduceExec::<i64, i64>::new(Arc::new(|a, b| a + b), errs());
        col_op.restore(snap);
        let _ = col_op.process_columns(pairs(100..200));
        let mut out = Vec::new();
        col_op.flush(&mut out);

        // baseline: one columnar executor sees the whole stream
        let mut base = ColumnReduceExec::<i64, i64>::new(Arc::new(|a, b| a + b), errs());
        let _ = base.process_columns(pairs(0..200));
        let mut expect = Vec::new();
        base.flush(&mut expect);
        assert_eq!(sorted(out), sorted(expect));
    }

    #[test]
    fn fold_snapshot_round_trips_across_planes() {
        // row → columnar: fold half the stream classically, snapshot,
        // restore columnar, fold the rest — totals match a single run
        let pairs = |r: std::ops::Range<i64>| {
            column_batch_of(&Layout::pair(Layout::I64, Layout::I64), r.map(|i| (i % 7, i)))
        };
        let step_rows = || {
            Arc::new(|acc: &mut Value, v: Value| {
                *acc = Value::I64(acc.as_i64().unwrap() + v.as_i64().unwrap())
            })
        };
        let mut row_op = FoldExec::new(Value::I64(0), step_rows());
        let mut sink = Vec::new();
        row_op.process(ChainInput::Shared(pairs(0..150).to_batch()), &mut sink);
        let snap = row_op.snapshot().expect("state present");

        let mut col_op =
            ColumnFoldExec::<i64, i64, i64>::new(0, Arc::new(|acc, x| *acc += x), errs());
        col_op.restore(snap);
        let _ = col_op.process_columns(pairs(150..300));
        let mut out = Vec::new();
        col_op.flush(&mut out);

        let mut base = FoldExec::new(Value::I64(0), step_rows());
        base.process(ChainInput::Shared(pairs(0..300).to_batch()), &mut sink);
        let mut expect = Vec::new();
        base.flush(&mut expect);
        assert_eq!(sorted(out), sorted(expect));
    }

    #[test]
    fn window_snapshot_round_trips_across_planes() {
        // columnar → row: partial windows snapshotted under the columnar
        // plane land in the classic executor and close there
        let layout = Layout::pair(Layout::I64, Layout::F64);
        let pairs = |r: std::ops::Range<i64>| column_batch_of(&layout, r.map(|i| (i % 4, i as f64)));
        let mut col_op = ColumnWindowExec::new(16, 16, WindowAgg::Sum, Layout::I64, Layout::F64);
        let mut emitted = match col_op.process_columns(pairs(0..100)) {
            ColumnFlow::Rows(rows) => rows,
            _ => panic!("window emits rows"),
        };
        let snap = col_op.snapshot().expect("partial windows present");
        let mut row_op = crate::runtime::exec::WindowExec::new(16, 16, WindowAgg::Sum);
        row_op.restore(snap);
        row_op.process(ChainInput::Shared(pairs(100..200).to_batch()), &mut emitted);
        row_op.flush(&mut emitted);

        // baseline: one row executor sees the whole stream
        let mut base = crate::runtime::exec::WindowExec::new(16, 16, WindowAgg::Sum);
        let mut expect = Vec::new();
        base.process(ChainInput::Shared(pairs(0..200).to_batch()), &mut expect);
        base.flush(&mut expect);
        assert_eq!(sorted(emitted), sorted(expect));
    }

    #[test]
    fn columnar_assigner_mints_watermarks_and_passes_columns_through() {
        let mut op = ColumnAssignTsExec::<i64>::new(
            Arc::new(|x| *x),
            WatermarkGen::BoundedOutOfOrderness { bound_ms: 5 },
            errs(),
        );
        let cb = i64_batch(100);
        match op.process_columns(cb.clone()) {
            ColumnFlow::Columns(same) => assert!(
                ColumnBatch::ptr_eq(&same, &cb),
                "assigner scans, never rebuilds"
            ),
            _ => panic!("assigner keeps the chain columnar"),
        }
        assert_eq!(op.take_watermark(), Some(94), "max ts 99 minus bound 5");
        assert_eq!(op.take_watermark(), None, "promise did not advance");

        // the snapshot restores into the CLASSIC assigner without
        // regressing the promise
        let snap = op.snapshot().expect("generator state present");
        let mut row_op = crate::runtime::exec::AssignTsExec::new(
            Arc::new(|v: &Value| v.as_i64().unwrap_or(0)),
            WatermarkGen::BoundedOutOfOrderness { bound_ms: 5 },
        );
        row_op.restore(snap);
        let mut out = Vec::new();
        row_op.process(ChainInput::Shared(Batch::new(vec![Value::I64(50)])), &mut out);
        assert_eq!(out, vec![Value::I64(50)]);
        assert_eq!(
            row_op.take_watermark(),
            None,
            "older data after restore never lowers the watermark"
        );
    }

    #[test]
    fn columnar_event_window_matches_value_event_window() {
        let ts = || Arc::new(|v: &Value| v.as_i64().unwrap_or(0)) as crate::time::TsFn;
        let assigner = crate::time::WindowAssigner::Tumbling { size_ms: 10 };
        let keyed = column_batch_of(
            &Layout::pair(Layout::I64, Layout::I64),
            (0..100i64).map(|i| (i % 4, i)),
        );
        let mut col_op = ColumnEventWindowExec::new(
            EventWindowExec::new(ts(), assigner, WindowAgg::Count, 0),
            Layout::I64,
            Layout::I64,
        );
        let mut row_op = EventWindowExec::new(ts(), assigner, WindowAgg::Count, 0);

        match col_op.process_columns(keyed.clone()) {
            ColumnFlow::Rows(rows) => assert!(rows.is_empty(), "panes buffer until the watermark"),
            _ => panic!("event window emits rows"),
        }
        let mut sink = Vec::new();
        row_op.process(ChainInput::Shared(keyed.to_batch()), &mut sink);

        let mut got = Vec::new();
        let mut expect = Vec::new();
        assert_eq!(col_op.on_watermark(50, &mut got), Some(50));
        assert_eq!(row_op.on_watermark(50, &mut expect), Some(50));
        assert_eq!(got, expect);
        assert_eq!(got.len(), 20, "5 closed panes x 4 keys");

        got.clear();
        expect.clear();
        col_op.flush(&mut got);
        row_op.flush(&mut expect);
        assert_eq!(got, expect);
    }

    #[test]
    fn event_window_snapshot_round_trips_across_planes() {
        // columnar → row: panes buffered (and a clock advanced) under the
        // columnar plane land in the classic executor and fire there
        let ts = || Arc::new(|v: &Value| v.as_i64().unwrap_or(0)) as crate::time::TsFn;
        let assigner = crate::time::WindowAssigner::Tumbling { size_ms: 10 };
        let layout = Layout::pair(Layout::I64, Layout::I64);
        let pairs = |r: std::ops::Range<i64>| column_batch_of(&layout, r.map(|i| (i % 4, i)));

        let mut col_op = ColumnEventWindowExec::new(
            EventWindowExec::new(ts(), assigner, WindowAgg::Count, 0),
            Layout::I64,
            Layout::I64,
        );
        let _ = col_op.process_columns(pairs(0..50));
        let mut emitted = Vec::new();
        col_op.on_watermark(30, &mut emitted);
        let snap = col_op.snapshot().expect("open panes and a clock");

        let mut row_op = EventWindowExec::new(ts(), assigner, WindowAgg::Count, 0);
        row_op.restore(snap);
        row_op.process(ChainInput::Shared(pairs(50..100).to_batch()), &mut emitted);
        row_op.flush(&mut emitted);

        // baseline: one row executor sees the whole stream with the same
        // watermark sequence
        let mut base = EventWindowExec::new(ts(), assigner, WindowAgg::Count, 0);
        let mut expect = Vec::new();
        base.process(ChainInput::Shared(pairs(0..50).to_batch()), &mut expect);
        base.on_watermark(30, &mut expect);
        base.process(ChainInput::Shared(pairs(50..100).to_batch()), &mut expect);
        base.flush(&mut expect);
        assert_eq!(emitted, expect);
    }

    #[test]
    fn decode_failures_on_the_row_path_are_recorded_not_poisonous() {
        let e = errs();
        let mut op = ColumnMapExec::<i64, i64>::new(Arc::new(|x| x + 1), e.clone());
        let batch = Batch::new(vec![Value::I64(1), Value::Str("bad".into()), Value::I64(2)]);
        let mut out = Vec::new();
        op.process(ChainInput::Shared(batch), &mut out);
        assert_eq!(out, vec![Value::I64(2), Value::I64(3)]);
        assert_eq!(e.count(), 1);
    }
}
