//! Runtime metrics: counters for events, bytes per link, zone crossings.
//!
//! One [`MetricsRegistry`] is created per job execution and shared (Arc)
//! across all operator instances, link threads, and the coordinator. All
//! counters are lock-free atomics so the hot path never blocks on metrics.

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Duration;

/// Shared handle to the per-job metrics registry.
pub type Metrics = Arc<MetricsRegistry>;

/// Per-job metrics registry.
#[derive(Debug, Default)]
pub struct MetricsRegistry {
    /// Events emitted by sources.
    pub events_in: AtomicU64,
    /// Events delivered to sinks.
    pub events_out: AtomicU64,
    /// Bytes serialized onto emulated network links.
    pub net_bytes: AtomicU64,
    /// Frames sent over emulated links.
    pub net_frames: AtomicU64,
    /// Events that crossed a zone boundary.
    pub zone_crossings: AtomicU64,
    /// Batch wire encodes actually performed by the channel layer
    /// (encode-once accounting: shared batches hitting several crossing
    /// edges count a single encode).
    pub batch_encodes: AtomicU64,
    /// Records appended to queue topics.
    pub queue_appends: AtomicU64,
    /// Records consumed from queue topics.
    pub queue_reads: AtomicU64,
    /// Queue consumer wait-set wakeups that delivered data (event-driven
    /// consumption: appends/closes wake parked consumers).
    pub queue_wakeups: AtomicU64,
    /// Queue consumer waits that expired without data (idle poll
    /// timeouts; a healthy loaded consumer is wakeup-driven instead).
    pub queue_wait_timeouts: AtomicU64,
    /// Chain-interior buffer hand-offs served by a recycled allocation
    /// (steady-state operator chains allocate nothing per operator).
    pub chain_buffer_reuses: AtomicU64,
    /// Chain buffer (re)allocations: warmup growth plus the one
    /// chain-edge `Batch` payload per invocation whose allocation departs
    /// downstream.
    pub chain_buffer_allocs: AtomicU64,
    /// XLA executions performed on the hot path.
    pub xla_calls: AtomicU64,
    /// Rows (windows) scored through XLA.
    pub xla_rows: AtomicU64,
    /// Corrupt queue records skipped by consumers (each one is a record
    /// that failed to decode; the job keeps running instead of aborting).
    pub corrupt_records: AtomicU64,
    /// Source inputs that became unreadable after deploy-time validation
    /// (e.g. a source file deleted mid-run); the affected instance
    /// produces nothing instead of panicking.
    pub source_errors: AtomicU64,
    /// Epoch markers forwarded between instances during drain-and-handoff
    /// dynamic updates.
    pub epochs_forwarded: AtomicU64,
    /// Event-time watermark frames forwarded between instances (one per
    /// target edge, like `epochs_forwarded`).
    pub watermarks_forwarded: AtomicU64,
    /// Worst observed end-to-end watermark propagation latency in
    /// milliseconds: wall-clock at a fan-in merge minus the generation
    /// time stamped by the originating assigner (a high-water gauge, not
    /// a counter).
    pub watermark_lag_ms: AtomicU64,
    /// Records that arrived with an event timestamp at or below an
    /// event-time operator's expired horizon (watermark minus allowed
    /// lateness): counted — and optionally routed to a side output —
    /// instead of silently dropped.
    pub late_records: AtomicU64,
    /// State-topic compactions: superseded checkpoint epochs truncated
    /// from per-unit state topics after a newer commit record landed.
    pub state_compactions: AtomicU64,
    /// Milliseconds spent quiescing + respawning units across all dynamic
    /// updates (the total update pause window).
    pub update_pause_ms: AtomicU64,
    /// Checkpoint records committed to per-unit state topics (one per
    /// unit-zone per completed checkpoint epoch).
    pub checkpoints_taken: AtomicU64,
    /// State-topic appends that failed (closed topic, poisoned partition).
    /// A failed append means the checkpoint/handoff record was *dropped* —
    /// surfaced here instead of silently discarded.
    pub state_append_failures: AtomicU64,
    /// Unit-zone recoveries performed after an instance thread died
    /// (respawn from last committed checkpoint + replay).
    pub recoveries: AtomicU64,
    /// Autoscaler scale-up actions (replication raised under lag).
    pub autoscale_ups: AtomicU64,
    /// Autoscaler scale-down actions (replication lowered when lag drained).
    pub autoscale_downs: AtomicU64,
    /// Bytes written to real transport sockets (length prefixes included).
    pub transport_bytes_sent: AtomicU64,
    /// Bytes read from real transport sockets.
    pub transport_bytes_recv: AtomicU64,
    /// Frames written to real transport sockets (data + control).
    pub transport_frames_sent: AtomicU64,
    /// Frames read from real transport sockets (data + control).
    pub transport_frames_recv: AtomicU64,
    /// Successful reconnect / re-adoption handshakes after a peer or
    /// coordinator came back.
    pub transport_reconnects: AtomicU64,
    /// Delivery failures on closed/poisoned lanes and malformed frames —
    /// counted (per satellite hardening) instead of panicking the
    /// delivering thread.
    pub transport_errors: AtomicU64,
    /// Records dropped by a broker overload policy (`Shed(DropOldest)` /
    /// `Shed(Sample)`) — never silent: every shed record is counted here.
    pub records_shed: AtomicU64,
    /// Records re-read from a segment file because their in-memory bytes
    /// had been evicted under the broker memory budget (spill path).
    pub spill_reads: AtomicU64,
    /// High-water gauge of broker-resident queue bytes (record bodies
    /// held in memory across all partitions of a budgeted broker).
    pub resident_bytes: AtomicU64,
    /// Partial/CRC-failed *final* frames truncated from segment files
    /// during recovery (the normal kill -9 artifact; mid-log corruption
    /// still errors).
    pub torn_tails_truncated: AtomicU64,
    /// Labelled counters (per-link bytes, per-operator events, ...).
    labelled: Mutex<BTreeMap<String, Arc<AtomicU64>>>,
}

impl MetricsRegistry {
    /// Creates a fresh registry wrapped for sharing.
    pub fn new() -> Metrics {
        Arc::new(MetricsRegistry::default())
    }

    /// Returns (creating if needed) a labelled counter, e.g.
    /// `link.E1->S1.bytes` or `op.3.events`.
    pub fn counter(&self, name: &str) -> Arc<AtomicU64> {
        let mut m = self.labelled.lock().unwrap();
        m.entry(name.to_string())
            .or_insert_with(|| Arc::new(AtomicU64::new(0)))
            .clone()
    }

    /// Snapshot of all labelled counters.
    pub fn labelled_snapshot(&self) -> BTreeMap<String, u64> {
        self.labelled
            .lock()
            .unwrap()
            .iter()
            .map(|(k, v)| (k.clone(), v.load(Ordering::Relaxed)))
            .collect()
    }

    /// Adds to a builtin counter.
    pub fn add(counter: &AtomicU64, n: u64) {
        counter.fetch_add(n, Ordering::Relaxed);
    }

    /// Raises a high-water gauge to `n` if `n` exceeds its current value.
    pub fn fetch_max(counter: &AtomicU64, n: u64) {
        counter.fetch_max(n, Ordering::Relaxed);
    }

    /// Renders a human-readable report.
    pub fn render(&self, wall: Duration) -> String {
        use crate::util::{fmt_bytes, fmt_rate};
        let ein = self.events_in.load(Ordering::Relaxed);
        let eout = self.events_out.load(Ordering::Relaxed);
        let nb = self.net_bytes.load(Ordering::Relaxed);
        let mut s = String::new();
        s.push_str(&format!("wall time        : {wall:?}\n"));
        s.push_str(&format!(
            "events in / out  : {ein} / {eout} ({})\n",
            fmt_rate(ein, wall)
        ));
        s.push_str(&format!(
            "net bytes/frames : {} / {}\n",
            fmt_bytes(nb),
            self.net_frames.load(Ordering::Relaxed)
        ));
        s.push_str(&format!(
            "zone crossings   : {}\n",
            self.zone_crossings.load(Ordering::Relaxed)
        ));
        let be = self.batch_encodes.load(Ordering::Relaxed);
        if be > 0 {
            s.push_str(&format!("wire encodes     : {be}\n"));
        }
        let qa = self.queue_appends.load(Ordering::Relaxed);
        let qr = self.queue_reads.load(Ordering::Relaxed);
        if qa + qr > 0 {
            s.push_str(&format!("queue app/read   : {qa} / {qr}\n"));
        }
        let qw = self.queue_wakeups.load(Ordering::Relaxed);
        let qt = self.queue_wait_timeouts.load(Ordering::Relaxed);
        if qw + qt > 0 {
            s.push_str(&format!("queue wake/tmout : {qw} / {qt}\n"));
        }
        let br = self.chain_buffer_reuses.load(Ordering::Relaxed);
        let ba = self.chain_buffer_allocs.load(Ordering::Relaxed);
        if br + ba > 0 {
            s.push_str(&format!("chain reuse/alloc: {br} / {ba}\n"));
        }
        let cr = self.corrupt_records.load(Ordering::Relaxed);
        if cr > 0 {
            s.push_str(&format!("corrupt records  : {cr} (skipped)\n"));
        }
        let se = self.source_errors.load(Ordering::Relaxed);
        if se > 0 {
            s.push_str(&format!("source errors    : {se} (inputs skipped)\n"));
        }
        let ef = self.epochs_forwarded.load(Ordering::Relaxed);
        let up = self.update_pause_ms.load(Ordering::Relaxed);
        if ef + up > 0 {
            s.push_str(&format!("update epochs/ms : {ef} / {up}\n"));
        }
        let wf = self.watermarks_forwarded.load(Ordering::Relaxed);
        if wf > 0 {
            s.push_str(&format!(
                "watermarks fw/lag: {wf} / {}ms\n",
                self.watermark_lag_ms.load(Ordering::Relaxed)
            ));
        }
        let lr = self.late_records.load(Ordering::Relaxed);
        if lr > 0 {
            s.push_str(&format!("late records     : {lr} (counted, not dropped)\n"));
        }
        let ck = self.checkpoints_taken.load(Ordering::Relaxed);
        if ck > 0 {
            s.push_str(&format!("checkpoints      : {ck}\n"));
        }
        let sc = self.state_compactions.load(Ordering::Relaxed);
        if sc > 0 {
            s.push_str(&format!("state compactions: {sc}\n"));
        }
        let saf = self.state_append_failures.load(Ordering::Relaxed);
        if saf > 0 {
            s.push_str(&format!("state app fails  : {saf} (records dropped)\n"));
        }
        let rc = self.recoveries.load(Ordering::Relaxed);
        if rc > 0 {
            s.push_str(&format!("recoveries       : {rc}\n"));
        }
        let au = self.autoscale_ups.load(Ordering::Relaxed);
        let ad = self.autoscale_downs.load(Ordering::Relaxed);
        if au + ad > 0 {
            s.push_str(&format!("autoscale up/down: {au} / {ad}\n"));
        }
        let tb = self.transport_bytes_sent.load(Ordering::Relaxed)
            + self.transport_bytes_recv.load(Ordering::Relaxed);
        if tb > 0 {
            s.push_str(&format!(
                "sock bytes s/r   : {} / {}\n",
                fmt_bytes(self.transport_bytes_sent.load(Ordering::Relaxed)),
                fmt_bytes(self.transport_bytes_recv.load(Ordering::Relaxed))
            ));
            s.push_str(&format!(
                "sock frames s/r  : {} / {}\n",
                self.transport_frames_sent.load(Ordering::Relaxed),
                self.transport_frames_recv.load(Ordering::Relaxed)
            ));
        }
        let tr = self.transport_reconnects.load(Ordering::Relaxed);
        if tr > 0 {
            s.push_str(&format!("sock reconnects  : {tr}\n"));
        }
        let te = self.transport_errors.load(Ordering::Relaxed);
        if te > 0 {
            s.push_str(&format!("transport errors : {te} (counted, not fatal)\n"));
        }
        let rs = self.records_shed.load(Ordering::Relaxed);
        if rs > 0 {
            s.push_str(&format!("records shed     : {rs} (overload policy)\n"));
        }
        let sr = self.spill_reads.load(Ordering::Relaxed);
        if sr > 0 {
            s.push_str(&format!("spill reads      : {sr}\n"));
        }
        let rb = self.resident_bytes.load(Ordering::Relaxed);
        if rb > 0 {
            s.push_str(&format!(
                "resident bytes   : {} (high-water)\n",
                crate::util::fmt_bytes(rb)
            ));
        }
        let tt = self.torn_tails_truncated.load(Ordering::Relaxed);
        if tt > 0 {
            s.push_str(&format!("torn tails       : {tt} (truncated)\n"));
        }
        let xc = self.xla_calls.load(Ordering::Relaxed);
        if xc > 0 {
            s.push_str(&format!(
                "xla calls/rows   : {xc} / {}\n",
                self.xla_rows.load(Ordering::Relaxed)
            ));
        }
        for (k, v) in self.labelled_snapshot() {
            if k.contains("bytes") {
                s.push_str(&format!("{k:<17}: {}\n", fmt_bytes(v)));
            } else {
                s.push_str(&format!("{k:<17}: {v}\n"));
            }
        }
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn labelled_counters_are_shared() {
        let m = MetricsRegistry::new();
        let a = m.counter("link.E1->S1.bytes");
        let b = m.counter("link.E1->S1.bytes");
        a.fetch_add(10, Ordering::Relaxed);
        b.fetch_add(5, Ordering::Relaxed);
        assert_eq!(m.labelled_snapshot()["link.E1->S1.bytes"], 15);
    }

    #[test]
    fn builtin_counters_accumulate() {
        let m = MetricsRegistry::new();
        MetricsRegistry::add(&m.events_in, 100);
        MetricsRegistry::add(&m.events_in, 23);
        assert_eq!(m.events_in.load(Ordering::Relaxed), 123);
    }

    #[test]
    fn render_contains_key_lines() {
        let m = MetricsRegistry::new();
        MetricsRegistry::add(&m.events_in, 5);
        let r = m.render(Duration::from_secs(1));
        assert!(r.contains("events in / out"));
        assert!(r.contains("net bytes/frames"));
    }
}
