//! Cluster configuration file parsing — the paper's "configuration file"
//! that names layers, zones, hosts (with capabilities), inter-zone link
//! conditions, and the queue topics used between FlowUnits (paper §IV).
//!
//! Format: INI-like sections, one entity per section.
//!
//! ```text
//! layers = edge, site, cloud
//!
//! [zone E1]
//! layer = edge
//! locations = L1
//! parent = S1
//!
//! [host e1]
//! zone = E1
//! cores = 1
//! cap.gpu = no
//!
//! [link E1 S1]          # ordered child/parent zone pair; applied both ways
//! bandwidth = 100Mbit
//! latency = 10ms
//!
//! [defaults]
//! bandwidth = unlimited  # for tree edges without an explicit [link]
//! latency = 0ms
//! ```

use crate::error::{Error, Result};
use crate::netsim::LinkSpec;
use crate::topology::{CapValue, Capabilities, Host, Topology, Zone, ZoneId};
use std::collections::BTreeMap;
use std::time::Duration;

/// Parsed cluster specification: the topology plus link conditions.
#[derive(Debug, Clone, Default)]
pub struct ClusterSpec {
    /// Continuum topology (zones, hosts, layers).
    pub topology: Topology,
    /// Explicit link conditions keyed by `(child_zone, parent_zone)`.
    pub links: BTreeMap<(ZoneId, ZoneId), LinkSpec>,
    /// Default link conditions for unlisted tree edges.
    pub default_link: LinkSpec,
}

impl ClusterSpec {
    /// Parses a cluster spec from the configuration text.
    pub fn parse(text: &str) -> Result<ClusterSpec> {
        let mut spec = ClusterSpec::default();
        let mut section: Option<SectionHead> = None;
        let mut body: Vec<(usize, String, String)> = Vec::new();

        let flush = |spec: &mut ClusterSpec,
                         section: &Option<SectionHead>,
                         body: &mut Vec<(usize, String, String)>|
         -> Result<()> {
            if let Some(head) = section {
                apply_section(spec, head, body)?;
            }
            body.clear();
            Ok(())
        };

        for (lineno, raw) in text.lines().enumerate() {
            let line = strip_comment(raw).trim().to_string();
            if line.is_empty() {
                continue;
            }
            let n = lineno + 1;
            if line.starts_with('[') {
                if !line.ends_with(']') {
                    return Err(Error::Config {
                        line: n,
                        msg: format!("unterminated section header '{line}'"),
                    });
                }
                flush(&mut spec, &section, &mut body)?;
                section = Some(SectionHead::parse(&line[1..line.len() - 1], n)?);
            } else if let Some(eq) = line.find('=') {
                let key = line[..eq].trim().to_string();
                let val = line[eq + 1..].trim().to_string();
                if section.is_none() {
                    // top-level keys
                    if key == "layers" {
                        spec.topology.layers =
                            val.split(',').map(|s| s.trim().to_string()).collect();
                    } else {
                        return Err(Error::Config {
                            line: n,
                            msg: format!("unknown top-level key '{key}'"),
                        });
                    }
                } else {
                    body.push((n, key, val));
                }
            } else {
                return Err(Error::Config {
                    line: n,
                    msg: format!("expected 'key = value', got '{line}'"),
                });
            }
        }
        flush(&mut spec, &section, &mut body)?;
        spec.topology.validate()?;
        Ok(spec)
    }

    /// Loads and parses a config file from disk.
    pub fn load(path: &str) -> Result<ClusterSpec> {
        Self::parse(&std::fs::read_to_string(path)?)
    }

    /// Link conditions for the tree edge `(child, parent)`, falling back to
    /// the defaults. Lookup is direction-insensitive (the paper shapes both
    /// directions identically with `tc`).
    pub fn link_between(&self, child: &str, parent: &str) -> LinkSpec {
        self.links
            .get(&(child.to_string(), parent.to_string()))
            .or_else(|| self.links.get(&(parent.to_string(), child.to_string())))
            .cloned()
            .unwrap_or_else(|| self.default_link.clone())
    }

    /// Overrides every inter-zone link with the same conditions — used by
    /// the Fig. 3 sweep, which shapes all cross-zone traffic identically.
    pub fn set_uniform_links(&mut self, spec: LinkSpec) {
        self.links.clear();
        self.default_link = spec;
    }
}

#[derive(Debug)]
enum SectionHead {
    Zone(String),
    Host(String),
    Link(String, String),
    Defaults,
}

impl SectionHead {
    fn parse(s: &str, line: usize) -> Result<SectionHead> {
        let parts: Vec<&str> = s.split_whitespace().collect();
        match parts.as_slice() {
            ["zone", id] => Ok(SectionHead::Zone(id.to_string())),
            ["host", id] => Ok(SectionHead::Host(id.to_string())),
            ["link", a, b] => Ok(SectionHead::Link(a.to_string(), b.to_string())),
            ["defaults"] => Ok(SectionHead::Defaults),
            _ => Err(Error::Config {
                line,
                msg: format!("unknown section '[{s}]'"),
            }),
        }
    }
}

fn strip_comment(line: &str) -> &str {
    match line.find('#') {
        Some(i) => &line[..i],
        None => line,
    }
}

fn apply_section(
    spec: &mut ClusterSpec,
    head: &SectionHead,
    body: &[(usize, String, String)],
) -> Result<()> {
    match head {
        SectionHead::Zone(id) => {
            let mut zone = Zone {
                id: id.clone(),
                layer: String::new(),
                locations: Vec::new(),
                parent: None,
            };
            for (n, k, v) in body {
                match k.as_str() {
                    "layer" => zone.layer = v.clone(),
                    "locations" => {
                        zone.locations = v.split(',').map(|s| s.trim().to_string()).collect()
                    }
                    "parent" => zone.parent = Some(v.clone()),
                    _ => {
                        return Err(Error::Config {
                            line: *n,
                            msg: format!("unknown zone key '{k}'"),
                        })
                    }
                }
            }
            if zone.layer.is_empty() {
                return Err(Error::Config {
                    line: 0,
                    msg: format!("zone '{id}' missing 'layer'"),
                });
            }
            spec.topology.zones.insert(id.clone(), zone);
        }
        SectionHead::Host(id) => {
            let mut zone = String::new();
            let mut cores = 1usize;
            let mut caps = Capabilities::default();
            for (n, k, v) in body {
                if let Some(cap) = k.strip_prefix("cap.") {
                    caps.set(cap, CapValue::parse(v));
                } else {
                    match k.as_str() {
                        "zone" => zone = v.clone(),
                        "cores" => {
                            cores = v.parse().map_err(|_| Error::Config {
                                line: *n,
                                msg: format!("bad core count '{v}'"),
                            })?
                        }
                        _ => {
                            return Err(Error::Config {
                                line: *n,
                                msg: format!("unknown host key '{k}'"),
                            })
                        }
                    }
                }
            }
            if zone.is_empty() {
                return Err(Error::Config {
                    line: 0,
                    msg: format!("host '{id}' missing 'zone'"),
                });
            }
            // n_cpu is always derivable from the core count unless given.
            if caps.get("n_cpu").is_none() {
                caps.set("n_cpu", CapValue::Int(cores as i64));
            }
            spec.topology.hosts.insert(
                id.clone(),
                Host {
                    id: id.clone(),
                    zone,
                    cores,
                    caps,
                },
            );
        }
        SectionHead::Link(a, b) => {
            let mut link = LinkSpec::default();
            parse_link_body(&mut link, body)?;
            spec.links.insert((a.clone(), b.clone()), link);
        }
        SectionHead::Defaults => {
            let mut link = spec.default_link.clone();
            parse_link_body(&mut link, body)?;
            spec.default_link = link;
        }
    }
    Ok(())
}

fn parse_link_body(link: &mut LinkSpec, body: &[(usize, String, String)]) -> Result<()> {
    for (n, k, v) in body {
        match k.as_str() {
            "bandwidth" => {
                link.bandwidth_bps = crate::util::parse_bandwidth(v).ok_or(Error::Config {
                    line: *n,
                    msg: format!("bad bandwidth '{v}'"),
                })?
            }
            "latency" => {
                link.latency = crate::util::parse_duration(v).ok_or(Error::Config {
                    line: *n,
                    msg: format!("bad latency '{v}'"),
                })?
            }
            _ => {
                return Err(Error::Config {
                    line: *n,
                    msg: format!("unknown link key '{k}'"),
                })
            }
        }
    }
    Ok(())
}

/// Builds the paper's evaluation cluster (§V): 4 edge servers with 1 core
/// each in 4 zones, one site data centre with 2×4-core machines, one cloud
/// VM with 16 cores (annotated `gpu = yes` / `xla = yes` so the
/// capability-constrained analytics operators land there).
pub fn eval_cluster(bandwidth: Option<u64>, latency: Duration) -> ClusterSpec {
    let mut text = String::from("layers = edge, site, cloud\n");
    for i in 1..=4 {
        text.push_str(&format!(
            "[zone E{i}]\nlayer = edge\nlocations = L{i}\nparent = S1\n"
        ));
        text.push_str(&format!("[host e{i}]\nzone = E{i}\ncores = 1\n"));
    }
    text.push_str("[zone S1]\nlayer = site\nlocations = L1, L2, L3, L4\nparent = C1\n");
    text.push_str("[host s1a]\nzone = S1\ncores = 4\n[host s1b]\nzone = S1\ncores = 4\n");
    text.push_str("[zone C1]\nlayer = cloud\nlocations = L1, L2, L3, L4\n");
    text.push_str("[host c1]\nzone = C1\ncores = 16\ncap.gpu = yes\ncap.xla = yes\ncap.memory = 64GB\n");
    let mut spec = ClusterSpec::parse(&text).expect("eval cluster must parse");
    spec.set_uniform_links(LinkSpec {
        bandwidth_bps: bandwidth,
        latency,
    });
    spec
}

/// The Fig. 2 topology from the paper's running example (5 edges, 2 sites,
/// 1 cloud with mixed GPU/non-GPU hosts); locations L1..L5.
pub fn fig2_cluster() -> ClusterSpec {
    let text = r#"
layers = edge, site, cloud

[zone E1]
layer = edge
locations = L1
parent = S1
[zone E2]
layer = edge
locations = L2
parent = S1
[zone E3]
layer = edge
locations = L3
parent = S1
[zone E4]
layer = edge
locations = L4
parent = S2
[zone E5]
layer = edge
locations = L5
parent = S2

[zone S1]
layer = site
locations = L1, L2, L3
parent = C1
[zone S2]
layer = site
locations = L4, L5
parent = C1

[zone C1]
layer = cloud
locations = L1, L2, L3, L4, L5

[host e1]
zone = E1
cores = 1
[host e2]
zone = E2
cores = 1
[host e3]
zone = E3
cores = 1
[host e4]
zone = E4
cores = 1
[host e5]
zone = E5
cores = 1

[host s1a]
zone = S1
cores = 4
[host s2a]
zone = S2
cores = 4

[host c1gpu]
zone = C1
cores = 8
cap.gpu = yes
cap.xla = yes
cap.memory = 64GB
[host c1cpu]
zone = C1
cores = 8
cap.gpu = no
cap.memory = 32GB

[defaults]
bandwidth = 1Gbit
latency = 5ms
"#;
    ClusterSpec::parse(text).expect("fig2 cluster must parse")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_eval_cluster() {
        let spec = eval_cluster(Some(100_000_000), Duration::from_millis(10));
        assert_eq!(spec.topology.layers, vec!["edge", "site", "cloud"]);
        assert_eq!(spec.topology.zones_at_layer("edge").len(), 4);
        assert_eq!(spec.topology.total_cores(), 4 + 8 + 16);
        let l = spec.link_between("E1", "S1");
        assert_eq!(l.bandwidth_bps, Some(100_000_000));
        assert_eq!(l.latency, Duration::from_millis(10));
    }

    #[test]
    fn parses_fig2_cluster() {
        let spec = fig2_cluster();
        assert_eq!(spec.topology.zones.len(), 8);
        // defaults apply to unlisted links
        let l = spec.link_between("E5", "S2");
        assert_eq!(l.bandwidth_bps, Some(1_000_000_000));
        assert_eq!(l.latency, Duration::from_millis(5));
        // gpu host carries the capability
        let gpu = ConstraintTest::gpu_hosts(&spec);
        assert_eq!(gpu, vec!["c1gpu"]);
    }

    struct ConstraintTest;
    impl ConstraintTest {
        fn gpu_hosts(spec: &ClusterSpec) -> Vec<String> {
            let e = crate::topology::ConstraintExpr::parse("gpu = yes").unwrap();
            spec.topology
                .matching_hosts("C1", Some(&e))
                .into_iter()
                .map(|h| h.id.clone())
                .collect()
        }
    }

    #[test]
    fn comments_and_blanks_ignored() {
        let spec = ClusterSpec::parse(
            "layers = edge, cloud\n# comment\n\n[zone E]\nlayer = edge # trailing\nlocations = L1\nparent = C\n[zone C]\nlayer = cloud\nlocations = L1\n[host h]\nzone = C\ncores = 2\n[host e]\nzone = E\ncores = 1\n",
        )
        .unwrap();
        assert_eq!(spec.topology.zones["E"].layer, "edge");
        assert_eq!(spec.topology.hosts["h"].cores, 2);
    }

    #[test]
    fn host_gets_default_ncpu_cap() {
        let spec = eval_cluster(None, Duration::ZERO);
        let h = &spec.topology.hosts["s1a"];
        assert_eq!(h.caps.get("n_cpu"), Some(&CapValue::Int(4)));
    }

    #[test]
    fn error_on_unknown_section() {
        let err = ClusterSpec::parse("layers = a\n[frobnicate x]\nk = v\n").unwrap_err();
        assert!(err.to_string().contains("unknown section"));
    }

    #[test]
    fn error_on_missing_equals() {
        let err = ClusterSpec::parse("layers = a\n[zone Z]\nlayer edge\n").unwrap_err();
        assert!(err.to_string().contains("expected 'key = value'"));
    }

    #[test]
    fn error_on_bad_bandwidth() {
        let err = ClusterSpec::parse(
            "layers = a\n[zone Z]\nlayer = a\nlocations = L\n[host h]\nzone = Z\n[link Z Z]\nbandwidth = warp9\n",
        )
        .unwrap_err();
        assert!(err.to_string().contains("bad bandwidth"));
    }

    #[test]
    fn error_surfaces_topology_validation() {
        // zone parent at same layer -> topology error
        let err = ClusterSpec::parse(
            "layers = edge, cloud\n[zone A]\nlayer = edge\nlocations = L1\nparent = B\n[zone B]\nlayer = edge\nlocations = L2\n[host h]\nzone = A\n",
        )
        .unwrap_err();
        assert!(matches!(err, Error::Topology(_)));
    }

    #[test]
    fn uniform_link_override() {
        let mut spec = fig2_cluster();
        spec.set_uniform_links(LinkSpec {
            bandwidth_bps: Some(10_000_000),
            latency: Duration::from_millis(100),
        });
        let l = spec.link_between("E1", "S1");
        assert_eq!(l.bandwidth_bps, Some(10_000_000));
        assert_eq!(l.latency, Duration::from_millis(100));
    }
}
