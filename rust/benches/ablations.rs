//! `cargo bench --bench ablations` — ablation benchmarks for the design
//! choices called out in DESIGN.md §4:
//!
//! * **A1** queue-decoupled vs direct-TCP FlowUnit boundaries (the
//!   overhead the paper chose not to measure in Fig. 3);
//! * **A2** cross-zone frame batch size vs throughput;
//! * **A3** capability-filtered placement of the XLA operator vs letting
//!   it run on every cloud host (requires `make artifacts`; skipped
//!   otherwise);
//! * **A4** intra-host hot-loop throughput (stateless fused chain) — the
//!   baseline for the §Perf targets.

use flowunits::api::raw::{JobConfig, PlannerKind, Source, StreamContext, WindowAgg};
use flowunits::config::{eval_cluster, fig2_cluster};
use flowunits::value::Value;
use std::time::Duration;

fn events() -> u64 {
    std::env::var("ABL_EVENTS")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(100_000)
}

fn eval_pipeline(ctx: &mut StreamContext, n: u64) {
    ctx.stream(Source::synthetic(n, |_, i| Value::I64(i as i64)))
        .to_layer("edge")
        .filter(|v| v.as_i64().unwrap() % 3 == 0)
        .to_layer("site")
        .key_by(|v| Value::I64(v.as_i64().unwrap() % 16))
        .window(100, WindowAgg::Mean)
        .to_layer("cloud")
        .map(|v| v)
        .collect_count();
}

fn a1_queue_vs_direct() {
    println!("\n## A1 — queue-decoupled vs direct FlowUnit boundaries");
    println!("{:<10} {:>10} {:>14} {:>12}", "transport", "wall(s)", "queue appends", "overhead");
    let mut direct_wall = 0.0;
    for decouple in [false, true] {
        let config = JobConfig {
            planner: PlannerKind::FlowUnits,
            decouple_units: decouple,
            poll_timeout: Duration::from_millis(5),
            ..Default::default()
        };
        let mut ctx = StreamContext::new(eval_cluster(Some(100_000_000), Duration::from_millis(10)), config);
        eval_pipeline(&mut ctx, events());
        let report = ctx.execute().expect("a1");
        let wall = report.wall_time.as_secs_f64();
        let appends = report
            .metrics
            .queue_appends
            .load(std::sync::atomic::Ordering::Relaxed);
        if !decouple {
            direct_wall = wall;
            println!("{:<10} {:>10.3} {:>14} {:>12}", "direct", wall, appends, "-");
        } else {
            println!(
                "{:<10} {:>10.3} {:>14} {:>11.1}%",
                "queue",
                wall,
                appends,
                100.0 * (wall - direct_wall) / direct_wall
            );
        }
    }
}

fn a2_batch_size() {
    println!("\n## A2 — cross-zone frame batch size (FlowUnits, 100Mbit/10ms)");
    println!("{:<10} {:>10} {:>12} {:>12}", "batch", "wall(s)", "frames", "bytes");
    for batch in [64usize, 256, 512, 2048, 8192] {
        let config = JobConfig {
            planner: PlannerKind::FlowUnits,
            batch_size: batch,
            ..Default::default()
        };
        let mut ctx = StreamContext::new(
            eval_cluster(Some(100_000_000), Duration::from_millis(10)),
            config,
        );
        eval_pipeline(&mut ctx, events());
        let report = ctx.execute().expect("a2");
        println!(
            "{:<10} {:>10.3} {:>12} {:>12}",
            batch,
            report.wall_time.as_secs_f64(),
            report
                .metrics
                .net_frames
                .load(std::sync::atomic::Ordering::Relaxed),
            report.net_bytes
        );
    }
}

fn a3_capability_placement() {
    if !std::path::Path::new("artifacts/anomaly_v1.hlo.txt").exists() {
        println!("\n## A3 — skipped (run `make artifacts`)");
        return;
    }
    println!("\n## A3 — XLA operator placement: capability-filtered vs everywhere");
    println!("{:<14} {:>10} {:>12}", "placement", "wall(s)", "xla calls");
    for constrained in [true, false] {
        let mut ctx = StreamContext::new(fig2_cluster(), JobConfig::default());
        let s = ctx
            .stream(Source::synthetic(events() / 2, |m, i| {
                let t = i as f64 * 0.01;
                Value::pair(
                    Value::I64(m as i64),
                    Value::F64(50.0 + 2.0 * (t * 0.37).sin() + m as f64),
                )
            }))
            .to_layer("edge")
            .filter(|v| v.as_pair().unwrap().1.as_f64().unwrap().is_finite())
            .to_layer("site")
            .key_by(|v| v.as_pair().unwrap().0.clone())
            .map(|keyed| {
                let (k, mr) = keyed.into_pair().unwrap();
                Value::pair(k, mr.into_pair().unwrap().1)
            })
            .window(32, WindowAgg::FeatureStats)
            .to_layer("cloud")
            .xla_map("anomaly_v1", 64, 5);
        if constrained {
            s.add_constraint("xla = yes").collect_count();
        } else {
            s.collect_count();
        }
        let report = ctx.execute().expect("a3");
        println!(
            "{:<14} {:>10.3} {:>12}",
            if constrained { "xla = yes" } else { "everywhere" },
            report.wall_time.as_secs_f64(),
            report
                .metrics
                .xla_calls
                .load(std::sync::atomic::Ordering::Relaxed),
        );
    }
    println!("(note: 'everywhere' also runs the artifact on non-accelerator hosts —");
    println!(" on real hardware that deployment is infeasible; here it shows the");
    println!(" planner honouring the paper's red/yellow node distinction)");
}

fn a4_hot_loop() {
    println!("\n## A4 — intra-host stateless hot loop (1 source core, transparent links)");
    println!("{:<12} {:>10} {:>14}", "events", "wall(s)", "throughput");
    let n = events() * 10;
    let mut text = String::from("layers = cloud\n");
    text.push_str("[zone C]\nlayer = cloud\nlocations = L\n[host c]\nzone = C\ncores = 2\n");
    let cluster = flowunits::config::ClusterSpec::parse(&text).unwrap();
    let mut ctx = StreamContext::new(cluster, JobConfig::default());
    ctx.stream(Source::synthetic(n, |_, i| Value::I64(i as i64)))
        .to_layer("cloud")
        .map(|v| Value::I64(v.as_i64().unwrap().wrapping_mul(31).wrapping_add(7)))
        .filter(|v| v.as_i64().unwrap() % 5 != 0)
        .map(|v| v)
        .discard();
    let report = ctx.execute().expect("a4");
    println!(
        "{:<12} {:>10.3} {:>14}",
        n,
        report.wall_time.as_secs_f64(),
        flowunits::util::fmt_rate(n, report.wall_time)
    );
}

fn main() {
    println!("# FlowUnits ablation benchmarks ({} events)", events());
    a1_queue_vs_direct();
    a2_batch_size();
    a3_capability_placement();
    a4_hot_loop();
}
