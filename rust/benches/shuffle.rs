//! `cargo bench --bench shuffle` — hot-path benchmarks for the PR-5
//! overhaul: zero-alloc operator chains, batch-granular hash shuffle, and
//! event-driven queue consumption. Four scenarios:
//!
//! * **linear** — end-to-end map/filter chain: throughput plus the
//!   buffer-reuse accounting (`chain_reuses` / `chain_allocs`) proving
//!   the steady-state chain path allocates nothing per operator;
//! * **keyed** — end-to-end `key_by → fold` pipeline: the hash column is
//!   produced where the key is built and consumed by the shuffle;
//! * **shuffle_micro** — the same record stream pushed through a real
//!   hash-routed `OutPort` twice: once **column-less** (the old cost
//!   model — `route_hash` re-walks every `Value` tree on the shuffle)
//!   and once **with the key-hash column**. `speedup` = new / old
//!   records-per-second; the keyed-shuffle acceptance bar is ≥ 1.3× at
//!   full size;
//! * **partitions** — one consumer owning 16 partitions with a paced
//!   producer: consumption must be driven by wait-set wakeups
//!   (`queue_wakeups`), not poll timeouts — the old per-partition
//!   timed-poll staircase had a 1 ms floor × N partitions.
//!
//! Results land in `BENCH_shuffle.json` (override with `SHUFFLE_OUT`);
//! `SHUFFLE_EVENTS` scales the workload, and CI runs a small smoke value.

use flowunits::api::raw::{JobConfig, JobReport, PlannerKind, Source, StreamContext};
use flowunits::channels::{route_hash, OutPort, Routing, Target};
use flowunits::config::eval_cluster;
use flowunits::queue::QueueBroker;
use flowunits::value::{Batch, Value};
use std::io::Write;
use std::sync::atomic::Ordering;
use std::sync::mpsc::sync_channel;
use std::time::{Duration, Instant};

fn events() -> u64 {
    std::env::var("SHUFFLE_EVENTS")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(1_000_000)
}

fn run_linear(n: u64) -> JobReport {
    let mut ctx = StreamContext::new(
        eval_cluster(None, Duration::ZERO),
        JobConfig {
            planner: PlannerKind::FlowUnits,
            ..Default::default()
        },
    );
    ctx.stream(Source::synthetic(n, |_, i| Value::I64(i as i64)))
        .to_layer("edge")
        .map(|v| Value::I64(v.as_i64().unwrap().wrapping_mul(31)))
        .filter(|v| v.as_i64().unwrap() % 7 != 0)
        .map(|v| Value::I64(v.as_i64().unwrap() >> 1))
        .to_layer("cloud")
        .collect_count();
    ctx.execute().expect("linear pipeline")
}

fn run_keyed(n: u64) -> JobReport {
    let mut ctx = StreamContext::new(
        eval_cluster(None, Duration::ZERO),
        JobConfig {
            planner: PlannerKind::FlowUnits,
            ..Default::default()
        },
    );
    ctx.stream(Source::synthetic(n, |_, i| {
        Value::Str(format!("sensor-{:04}", i % 512))
    }))
    .to_layer("edge")
    .to_layer("cloud")
    .key_by(|v| v.clone())
    .fold(Value::I64(0), |acc: &mut Value, _v: Value| {
        *acc = Value::I64(acc.as_i64().unwrap() + 1);
    })
    .collect_count();
    ctx.execute().expect("keyed pipeline")
}

/// Drives `batches` through a 4-target hash `OutPort` and returns
/// records/second. `with_column` toggles the key-hash column — without
/// it the port falls back to per-record `route_hash`, which is exactly
/// the old per-record shuffle's cost model.
fn shuffle_micro_once(rounds: usize, per_batch: usize, with_column: bool) -> f64 {
    // string keys: the tree-walk the column elides is a tag byte + length
    // + payload scan per record
    let template: Vec<Value> = (0..per_batch)
        .map(|i| {
            Value::pair(
                Value::Str(format!("device-{:05}", i % 257)),
                Value::I64(i as i64),
            )
        })
        .collect();
    let hashes: Vec<u64> = template.iter().map(route_hash).collect();
    let n_targets = 4;
    let mut targets = Vec::new();
    let mut rxs = Vec::new();
    for _ in 0..n_targets {
        // capacity sized so the timed section never blocks on delivery
        let (tx, rx) = sync_channel(rounds * per_batch / 16 + 1024);
        targets.push(Target::local(tx));
        rxs.push(rx);
    }
    let mut port = OutPort::new(targets, Routing::Hash, 1024, None);
    // pre-build batches in bounded chunks so the timed section contains
    // only the shuffle itself (hash + partition + delivery), not the
    // template cloning both variants pay identically
    let chunk = 64usize.min(rounds.max(1));
    let mut elapsed = Duration::ZERO;
    let mut sent = 0usize;
    while sent < rounds {
        let take = chunk.min(rounds - sent);
        let batches: Vec<Batch> = (0..take)
            .map(|_| {
                let values = template.clone();
                if with_column {
                    Batch::with_hashes(values, hashes.clone())
                } else {
                    Batch::new(values)
                }
            })
            .collect();
        let t0 = Instant::now();
        for b in batches {
            port.send(b);
        }
        elapsed += t0.elapsed();
        sent += take;
    }
    port.flush();
    let wall = elapsed.as_secs_f64();
    drop(port);
    let mut delivered = 0usize;
    for rx in rxs {
        while let Ok(msg) = rx.recv() {
            if let flowunits::channels::Msg::Batch(b) = msg {
                delivered += b.len();
            }
        }
    }
    assert_eq!(delivered, rounds * per_batch, "shuffle delivered every record");
    (rounds * per_batch) as f64 / wall.max(1e-9)
}

struct PartitionsResult {
    wall_s: f64,
    records: u64,
    wakeups: u64,
    timeouts: u64,
}

/// One consumer owning 16 partitions; a producer appends one record at a
/// time, paced, hashed across partitions. With the wait-set the consumer
/// parks once and every append wakes it directly.
fn run_partitions(records: u64) -> PartitionsResult {
    let m = flowunits::metrics::MetricsRegistry::new();
    let broker = QueueBroker::in_memory(Some(m.clone()));
    let topic = broker.topic("bench", 16).unwrap();
    topic.register_producer();
    let producer = {
        let topic = topic.clone();
        std::thread::spawn(move || {
            for i in 0..records {
                topic.append(i, &i.to_le_bytes()).unwrap();
                // pace the producer so the consumer is idle-parked between
                // appends (the scenario the timed-poll staircase serves
                // worst)
                std::thread::sleep(Duration::from_micros(300));
            }
            topic.producer_done();
        })
    };
    let parts: Vec<usize> = (0..16).collect();
    let mut offsets = vec![0usize; 16];
    let mut consumed = 0u64;
    let t0 = Instant::now();
    while let Some(drained) = topic.poll_many(&parts, &mut offsets, 64, Duration::from_secs(5)) {
        for (_, recs) in drained {
            consumed += recs.len() as u64;
        }
    }
    let wall = t0.elapsed().as_secs_f64();
    producer.join().unwrap();
    assert_eq!(consumed, records, "every record consumed exactly once");
    PartitionsResult {
        wall_s: wall,
        records,
        wakeups: m.queue_wakeups.load(Ordering::Relaxed),
        timeouts: m.queue_wait_timeouts.load(Ordering::Relaxed),
    }
}

fn report_row(name: &str, n: u64, r: &JobReport) -> String {
    let wall = r.wall_time.as_secs_f64();
    let reuses = r.metrics.chain_buffer_reuses.load(Ordering::Relaxed);
    let allocs = r.metrics.chain_buffer_allocs.load(Ordering::Relaxed);
    format!(
        "    {{\"name\": \"{name}\", \"events\": {n}, \"events_out\": {}, \
         \"wall_s\": {:.6}, \"throughput_ev_s\": {:.1}, \
         \"chain_reuses\": {reuses}, \"chain_allocs\": {allocs}}}",
        r.events_out,
        wall,
        if wall > 0.0 { n as f64 / wall } else { 0.0 },
    )
}

fn main() {
    let n = events();
    let full = n >= 500_000;
    println!("# FlowUnits hot-path benchmarks ({n} events per scenario)");

    let linear = run_linear(n);
    println!(
        "linear     {:>10.3}s  {:>14}  reuse/alloc {}/{}",
        linear.wall_time.as_secs_f64(),
        flowunits::util::fmt_rate(n, linear.wall_time),
        linear.metrics.chain_buffer_reuses.load(Ordering::Relaxed),
        linear.metrics.chain_buffer_allocs.load(Ordering::Relaxed),
    );

    let keyed = run_keyed(n);
    println!(
        "keyed      {:>10.3}s  {:>14}",
        keyed.wall_time.as_secs_f64(),
        flowunits::util::fmt_rate(n, keyed.wall_time),
    );

    // micro: interleave and repeat both variants, keep the best of each
    // (amortises scheduler noise the same way for both sides)
    let per_batch = 512usize;
    let rounds = ((n as usize / per_batch).max(8)).min(8192);
    let mut old_best = 0f64;
    let mut new_best = 0f64;
    for _ in 0..3 {
        old_best = old_best.max(shuffle_micro_once(rounds, per_batch, false));
        new_best = new_best.max(shuffle_micro_once(rounds, per_batch, true));
    }
    let speedup = new_best / old_best.max(1e-9);
    println!(
        "shuffle    old {:>12.0} rec/s   new {:>12.0} rec/s   speedup {speedup:.2}x",
        old_best, new_best
    );
    if full {
        assert!(
            speedup >= 1.3,
            "keyed-shuffle acceptance bar: pre-partitioned column shuffle \
             must beat the per-record tree-walk path by >= 1.3x, got {speedup:.2}x"
        );
    } else if speedup < 1.0 {
        // smoke measurements are milliseconds on a shared runner — the
        // ratio is reported, not gated, to keep CI noise-free; the 1.3x
        // bar is enforced at full size
        println!("note: smoke-mode speedup {speedup:.2}x (noise-prone; not gated)");
    }

    let pr = run_partitions(if full { 2000 } else { 300 });
    println!(
        "partitions {:>10.3}s  {} records  wakeups {}  timeouts {}",
        pr.wall_s, pr.records, pr.wakeups, pr.timeouts
    );
    assert!(
        pr.wakeups > pr.timeouts,
        "idle many-partition consumption must be wakeup-driven \
         (wakeups {} vs timeouts {})",
        pr.wakeups,
        pr.timeouts
    );

    let rows = vec![
        report_row("linear", n, &linear),
        report_row("keyed", n, &keyed),
        format!(
            "    {{\"name\": \"shuffle_micro\", \"records\": {}, \
             \"old_rec_s\": {:.1}, \"new_rec_s\": {:.1}, \"speedup\": {:.3}}}",
            rounds * per_batch,
            old_best,
            new_best,
            speedup
        ),
        format!(
            "    {{\"name\": \"partitions\", \"records\": {}, \"wall_s\": {:.6}, \
             \"wakeups\": {}, \"timeouts\": {}}}",
            pr.records, pr.wall_s, pr.wakeups, pr.timeouts
        ),
    ];
    let json = format!(
        "{{\n  \"bench\": \"shuffle\",\n  \"events\": {n},\n  \"scenarios\": [\n{}\n  ]\n}}\n",
        rows.join(",\n")
    );
    // cargo runs bench binaries with CWD = the package root (rust/);
    // SHUFFLE_OUT overrides the destination
    let path = std::env::var("SHUFFLE_OUT").unwrap_or_else(|_| "BENCH_shuffle.json".into());
    let mut f = std::fs::File::create(&path).expect("create BENCH_shuffle.json");
    f.write_all(json.as_bytes()).expect("write bench results");
    println!("\nwrote {path}");
}
