//! `cargo bench --bench dataplane` — data-plane benchmarks for the
//! zero-copy shared-`Batch` path: throughput and bytes-on-wire for the
//! three shapes that exercise it differently.
//!
//! * **linear** — edge → cloud chain, one crossing edge per batch: the
//!   encode-once baseline;
//! * **fanout** — a `split` into three sinks across two layers: batch
//!   duplication is refcount-only and the wire encode is shared across
//!   edges;
//! * **crossing** — edge → site → cloud keyed pipeline over shaped links:
//!   the paper's zone-crossing pressure case (bytes-on-wire is the metric
//!   the FlowUnits placement is meant to shrink).
//!
//! Results are written to `BENCH_dataplane.json` (throughput, bytes on
//! wire, frames, wire encodes per scenario) so perf drift is diffable
//! across PRs. `DATAPLANE_EVENTS` scales the workload; CI runs a small
//! smoke value so regressions in the bench itself fail fast.

use flowunits::api::raw::{JobConfig, JobReport, PlannerKind, Source, StreamContext, WindowAgg};
use flowunits::config::eval_cluster;
use flowunits::value::Value;
use std::io::Write;
use std::time::Duration;

fn events() -> u64 {
    std::env::var("DATAPLANE_EVENTS")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(200_000)
}

struct Row {
    name: &'static str,
    report: JobReport,
}

fn run_linear(n: u64) -> JobReport {
    let mut ctx = StreamContext::new(
        eval_cluster(None, Duration::ZERO),
        JobConfig {
            planner: PlannerKind::FlowUnits,
            ..Default::default()
        },
    );
    ctx.stream(Source::synthetic(n, |_, i| Value::I64(i as i64)))
        .to_layer("edge")
        .map(|v| Value::I64(v.as_i64().unwrap().wrapping_mul(31)))
        .filter(|v| v.as_i64().unwrap() % 7 != 0)
        .to_layer("cloud")
        .collect_count();
    ctx.execute().expect("linear pipeline")
}

fn run_fanout(n: u64) -> JobReport {
    let mut ctx = StreamContext::new(
        eval_cluster(None, Duration::ZERO),
        JobConfig {
            planner: PlannerKind::FlowUnits,
            ..Default::default()
        },
    );
    let s = ctx
        .stream(Source::synthetic(n, |_, i| Value::I64(i as i64)))
        .to_layer("edge");
    let (left, rest) = s.split();
    let (mid, right) = rest.split();
    left.unit("fan-site").to_layer("site").collect_count();
    mid.unit("fan-cloud-a").to_layer("cloud").collect_count();
    right.unit("fan-cloud-b").to_layer("cloud").collect_count();
    ctx.execute().expect("fanout pipeline")
}

fn run_crossing(n: u64) -> JobReport {
    let mut ctx = StreamContext::new(
        eval_cluster(Some(1_000_000_000), Duration::from_micros(200)),
        JobConfig {
            planner: PlannerKind::FlowUnits,
            ..Default::default()
        },
    );
    ctx.stream(Source::synthetic(n, |_, i| Value::I64(i as i64)))
        .to_layer("edge")
        .filter(|v| v.as_i64().unwrap() % 3 != 0)
        .to_layer("site")
        .key_by(|v| Value::I64(v.as_i64().unwrap() % 16))
        .window(100, WindowAgg::Mean)
        .to_layer("cloud")
        .collect_count();
    ctx.execute().expect("crossing pipeline")
}

fn json_row(row: &Row, n: u64) -> String {
    let r = &row.report;
    let wall = r.wall_time.as_secs_f64();
    let frames = r
        .metrics
        .net_frames
        .load(std::sync::atomic::Ordering::Relaxed);
    format!(
        "    {{\"name\": \"{}\", \"events\": {}, \"events_out\": {}, \
         \"wall_s\": {:.6}, \"throughput_ev_s\": {:.1}, \"net_bytes\": {}, \
         \"net_frames\": {}, \"wire_encodes\": {}, \"zone_crossings\": {}}}",
        row.name,
        n,
        r.events_out,
        wall,
        if wall > 0.0 { n as f64 / wall } else { 0.0 },
        r.net_bytes,
        frames,
        r.wire_encodes,
        r.zone_crossings,
    )
}

fn main() {
    let n = events();
    println!("# FlowUnits dataplane benchmarks ({n} events per scenario)");
    let rows = vec![
        Row { name: "linear", report: run_linear(n) },
        Row { name: "fanout", report: run_fanout(n) },
        Row { name: "crossing", report: run_crossing(n) },
    ];
    println!(
        "{:<10} {:>10} {:>14} {:>12} {:>10} {:>12}",
        "scenario", "wall(s)", "throughput", "net bytes", "frames", "encodes"
    );
    for row in &rows {
        let r = &row.report;
        let wall = r.wall_time.as_secs_f64();
        println!(
            "{:<10} {:>10.3} {:>14} {:>12} {:>10} {:>12}",
            row.name,
            wall,
            flowunits::util::fmt_rate(n, r.wall_time),
            r.net_bytes,
            r.metrics
                .net_frames
                .load(std::sync::atomic::Ordering::Relaxed),
            r.wire_encodes,
        );
        // the fan-out scenario is the zero-copy/encode-once proof: more
        // crossing frames than encodes means the cache did its job
        if row.name == "fanout" {
            let frames = r
                .metrics
                .net_frames
                .load(std::sync::atomic::Ordering::Relaxed);
            assert!(
                r.wire_encodes < frames,
                "encode-once violated: {} encodes for {} frames",
                r.wire_encodes,
                frames
            );
        }
    }
    let body = rows
        .iter()
        .map(|row| json_row(row, n))
        .collect::<Vec<_>>()
        .join(",\n");
    let json = format!(
        "{{\n  \"bench\": \"dataplane\",\n  \"events\": {n},\n  \"scenarios\": [\n{body}\n  ]\n}}\n"
    );
    // cargo runs bench binaries with CWD = the package root (rust/);
    // DATAPLANE_OUT overrides the destination
    let path = std::env::var("DATAPLANE_OUT").unwrap_or_else(|_| "BENCH_dataplane.json".into());
    let mut f = std::fs::File::create(&path).expect("create BENCH_dataplane.json");
    f.write_all(json.as_bytes()).expect("write bench results");
    println!("\nwrote {path}");
}
