//! `cargo bench --bench update_disruption` — measures what a dynamic
//! update actually costs while the pipeline is under load.
//!
//! The workload is the hot-swap stress shape: rate-limited edge sources
//! feed a stateful cloud FlowUnit (`key_by → window`, so the unit holds
//! keyed state *and* a direct internal hash channel between its stages),
//! and mid-run the unit is hot-swapped through the epoch drain-and-handoff
//! protocol. The bench reports:
//!
//! * source-side events/sec **before / during / after** the swap — the
//!   paper's claim is that producers are never disrupted;
//! * the **pause window**: the coordinator's measured quiesce+respawn time
//!   (`update_pause_ms`) and the longest observed sink-output stall
//!   overlapping the swap;
//! * conservation: the sum of emitted window counts must equal the events
//!   produced — zero loss, zero duplication, asserted on every run.
//!
//! Results land in `BENCH_update.json` (override with `UPDATE_OUT`).
//! `UPDATE_EVENTS`, `UPDATE_RATE`, and `UPDATE_SWAP_MS` scale the workload;
//! CI runs a small smoke configuration.

use flowunits::api::raw::{JobConfig, PlannerKind, Source, StreamContext, WindowAgg};
use flowunits::config::eval_cluster;
use flowunits::value::Value;
use std::io::Write;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

const KEYS: i64 = 16;
const WINDOW: usize = 100;

fn env_u64(name: &str, default: u64) -> u64 {
    std::env::var(name)
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(default)
}

fn config() -> JobConfig {
    JobConfig {
        planner: PlannerKind::FlowUnits,
        decouple_units: true,
        batch_size: 128,
        poll_timeout: Duration::from_millis(10),
        ..Default::default()
    }
}

/// source@edge → filter@edge ∥ "agg"@cloud: key_by → window(Count) →
/// collect. The window stage is fed by a direct internal hash channel.
fn graph(total: u64, rate: f64) -> flowunits::graph::LogicalGraph {
    let mut ctx = StreamContext::new(eval_cluster(None, Duration::ZERO), config());
    ctx.stream(Source::synthetic_rated(total, rate, |_, i| {
        Value::I64(i as i64)
    }))
    .to_layer("edge")
    .filter(|v| v.as_i64().unwrap() >= 0)
    .unit("agg")
    .to_layer("cloud")
    .key_by(|v| Value::I64(v.as_i64().unwrap() % KEYS))
    .window(WINDOW, WindowAgg::Count)
    .collect_vec();
    ctx.into_graph().expect("bench graph")
}

/// Mean source-side event rate over the sample window `[a, b]` seconds.
fn rate_in(samples: &[(f64, u64, u64)], a: f64, b: f64) -> f64 {
    // the first sample lands shortly after t=0, so a window starting at 0
    // anchors on it rather than finding no sample at all
    let lo = samples
        .iter()
        .filter(|s| s.0 <= a)
        .next_back()
        .or_else(|| samples.first());
    let hi = samples.iter().filter(|s| s.0 <= b).next_back();
    match (lo, hi) {
        (Some(&(t0, e0, _)), Some(&(t1, e1, _))) if t1 > t0 => {
            (e1 - e0) as f64 / (t1 - t0)
        }
        _ => 0.0,
    }
}

fn main() {
    let total = env_u64("UPDATE_EVENTS", 400_000);
    let rate = env_u64("UPDATE_RATE", 40_000) as f64;
    let swap_ms = env_u64("UPDATE_SWAP_MS", 400);
    println!(
        "# FlowUnits update-disruption bench ({total} events, {rate} ev/s per source, \
         swap at {swap_ms} ms)"
    );

    let coord = flowunits::coordinator::Coordinator::new(eval_cluster(None, Duration::ZERO), config());
    let mut dep = coord.deploy(&graph(total, rate)).expect("deploy");
    let metrics = dep.metrics();

    // sampler: (seconds since start, events_in, events_out) every ~5 ms
    let sampling = Arc::new(AtomicBool::new(true));
    let sampler = {
        let metrics = metrics.clone();
        let sampling = sampling.clone();
        let t0 = Instant::now();
        std::thread::spawn(move || {
            let mut samples: Vec<(f64, u64, u64)> = Vec::new();
            while sampling.load(Ordering::Relaxed) {
                samples.push((
                    t0.elapsed().as_secs_f64(),
                    metrics.events_in.load(Ordering::Relaxed),
                    metrics.events_out.load(Ordering::Relaxed),
                ));
                std::thread::sleep(Duration::from_millis(5));
            }
            samples
        })
    };

    let t0 = Instant::now();
    std::thread::sleep(Duration::from_millis(swap_ms));
    let swap_start = t0.elapsed().as_secs_f64();
    dep.update_unit("agg", graph(total, rate)).expect("hot swap");
    let swap_end = t0.elapsed().as_secs_f64();
    // observe the post-swap regime for as long as the pre-swap one
    std::thread::sleep(Duration::from_millis(swap_ms));

    sampling.store(false, Ordering::Relaxed);
    let samples = sampler.join().expect("sampler");
    let report = dep.wait().expect("job completes");

    // conservation: every produced event is counted in exactly one window
    let counted: i64 = report
        .collected
        .iter()
        .map(|v| v.as_pair().unwrap().1.as_i64().unwrap())
        .sum();
    assert_eq!(
        counted as u64, report.events_in,
        "zero-loss/zero-duplication violated across the swap"
    );
    assert_eq!(report.corrupt_records, 0);

    let before = rate_in(&samples, 0.0, swap_start);
    let during = rate_in(&samples, swap_start, swap_end.max(swap_start + 0.01));
    let after = rate_in(&samples, swap_end, swap_end + swap_ms as f64 / 1000.0);
    let pause_ms = report
        .metrics
        .update_pause_ms
        .load(Ordering::Relaxed);
    let epochs = report
        .metrics
        .epochs_forwarded
        .load(Ordering::Relaxed);

    // longest sink-output stall overlapping the swap window
    let mut stall = 0.0f64;
    if let Some(&(first_t, _, first_out)) = samples.first() {
        let mut run_start = first_t;
        let mut prev_out = first_out;
        for &(t, _, out) in &samples[1..] {
            if out > prev_out {
                if t >= swap_start && run_start <= swap_end {
                    stall = stall.max(t - run_start);
                }
                run_start = t;
                prev_out = out;
            }
        }
        // a stall still open when sampling stopped counts up to the last
        // sample — otherwise the worst run under-reports as ~0
        if let Some(&(last_t, _, _)) = samples.last() {
            if last_t >= swap_start && run_start <= swap_end {
                stall = stall.max(last_t - run_start);
            }
        }
    }

    println!(
        "{:<22} {:>12} {:>12} {:>12}",
        "", "before", "during", "after"
    );
    println!(
        "{:<22} {:>12.0} {:>12.0} {:>12.0}",
        "source events/s", before, during, after
    );
    println!("update call           : {:.1} ms", (swap_end - swap_start) * 1000.0);
    println!("pause (coordinator)   : {pause_ms} ms");
    println!("output stall observed : {:.1} ms", stall * 1000.0);
    println!("epoch markers         : {epochs}");
    println!(
        "events in/out         : {} / {} ({} windows)",
        report.events_in,
        report.events_out,
        report.collected.len()
    );

    let json = format!(
        "{{\n  \"bench\": \"update\",\n  \"events\": {total},\n  \"rate_per_source\": {rate},\n  \
         \"swap_at_ms\": {swap_ms},\n  \"before_ev_s\": {before:.1},\n  \"during_ev_s\": {during:.1},\n  \
         \"after_ev_s\": {after:.1},\n  \"update_call_ms\": {:.1},\n  \"pause_ms\": {pause_ms},\n  \
         \"output_stall_ms\": {:.1},\n  \"epochs_forwarded\": {epochs},\n  \"events_in\": {},\n  \
         \"windows_emitted\": {},\n  \"corrupt_records\": {}\n}}\n",
        (swap_end - swap_start) * 1000.0,
        stall * 1000.0,
        report.events_in,
        report.collected.len(),
        report.corrupt_records,
    );
    // cargo runs bench binaries with CWD = the package root (rust/);
    // UPDATE_OUT overrides the destination
    let path = std::env::var("UPDATE_OUT").unwrap_or_else(|_| "BENCH_update.json".into());
    let mut f = std::fs::File::create(&path).expect("create BENCH_update.json");
    f.write_all(json.as_bytes()).expect("write bench results");
    println!("\nwrote {path}");
}
