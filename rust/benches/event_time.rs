//! `cargo bench --bench event_time` — event-time subsystem benchmarks.
//! Three scenarios:
//!
//! * **proc_window** — the processing-time baseline: a keyed count
//!   window over the same disordered source, no timestamps, no
//!   watermarks. This is the cost floor the event-time path is compared
//!   against;
//! * **event_window** — the same source through `assign_timestamps`
//!   (bounded out-of-orderness watermarks) and a keyed tumbling
//!   event-time window. The delta vs `proc_window` is the price of
//!   event-time semantics: timestamp extraction, watermark frames, and
//!   pane buffering until the watermark fires them. Every run asserts
//!   conservation (pane counts sum to the input) and zero late records
//!   (the synthetic disorder stays within the watermark bound);
//! * **watermark_3hop** — the event-time pipeline stretched across
//!   edge → site → cloud, so every watermark crosses two shuffles and a
//!   min-of-inputs merge per hop. Reports `watermarks_forwarded` and
//!   the worst observed end-to-end propagation lag
//!   (`watermark_lag_ms`) alongside throughput.
//!
//! Results land in `BENCH_event_time.json` (override with
//! `EVENT_TIME_OUT`); `EVENT_TIME_EVENTS` scales the workload, and CI
//! runs a small smoke value.

use flowunits::api::raw::{
    JobConfig, JobReport, PlannerKind, Source, StreamContext, WatermarkGen, WindowAgg,
    WindowAssigner,
};
use flowunits::config::eval_cluster;
use flowunits::value::Value;
use std::io::Write;
use std::sync::atomic::Ordering;
use std::time::Duration;

fn events() -> u64 {
    std::env::var("EVENT_TIME_EVENTS")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(1_000_000)
}

/// Deterministically disordered event timestamps: blocks of 8 ticks
/// delivered back-to-front, 5 ms apart — at most 35 ms of disorder,
/// safely inside the 40 ms watermark bound used below.
fn disordered_ts(i: u64) -> i64 {
    let tick = (i / 8) * 8 + (7 - i % 8);
    tick as i64 * 5
}

fn ctx() -> StreamContext {
    StreamContext::new(
        eval_cluster(None, Duration::ZERO),
        JobConfig {
            planner: PlannerKind::FlowUnits,
            ..Default::default()
        },
    )
}

/// Processing-time baseline: keyed count windows, no event-time at all.
fn run_proc_window(n: u64) -> JobReport {
    let mut c = ctx();
    c.stream(Source::synthetic(n, |_, i| Value::I64(disordered_ts(i))))
        .to_layer("edge")
        .to_layer("cloud")
        .key_by(|v| Value::I64((v.as_i64().unwrap_or(0) / 5) % 64))
        .window(100, WindowAgg::Count)
        .collect_vec();
    c.execute().expect("proc_window pipeline")
}

/// Event-time tumbling windows behind bounded-out-of-orderness
/// watermarks; `three_hop` stretches the two-layer (edge → cloud) shape
/// into three (edge → site → cloud).
fn run_event_window(n: u64, three_hop: bool) -> JobReport {
    let mut c = ctx();
    let mut s = c
        .stream(Source::synthetic(n, |_, i| Value::I64(disordered_ts(i))))
        .to_layer("edge")
        .assign_timestamps(|v| v.as_i64().unwrap_or(0), WatermarkGen::bounded(40));
    if three_hop {
        // an extra site hop: every watermark crosses one more shuffle
        // and one more min-of-inputs merge before it can fire a pane
        s = s
            .to_layer("site")
            .filter(|v| v.as_i64().unwrap_or(0) >= 0);
    }
    s.to_layer("cloud")
        .key_by(|v| Value::I64((v.as_i64().unwrap_or(0) / 5) % 64))
        .event_window(
            |v| v.as_i64().unwrap_or(0),
            WindowAssigner::tumbling(500),
            WindowAgg::Count,
            0,
        )
        .collect_vec();
    c.execute().expect("event_window pipeline")
}

/// Panes must account for every input record, and none may be late: the
/// disorder is bounded by construction, so any loss or lateness is a
/// watermark-propagation bug, at smoke size as much as at full size.
fn assert_exact(name: &str, n: u64, r: &JobReport) {
    let paned: i64 = r
        .collected
        .iter()
        .map(|v| {
            v.as_pair()
                .and_then(|(_, c)| c.as_i64())
                .expect("(key, count) pane output")
        })
        .sum();
    assert_eq!(paned as u64, n, "{name}: every record lands in exactly one pane");
    let late = r.metrics.late_records.load(Ordering::Relaxed);
    assert_eq!(late, 0, "{name}: disorder stays within the watermark bound");
}

fn report_row(name: &str, n: u64, r: &JobReport) -> String {
    let wall = r.wall_time.as_secs_f64();
    format!(
        "    {{\"name\": \"{name}\", \"events\": {n}, \"wall_s\": {:.6}, \
         \"throughput_ev_s\": {:.1}, \"late_records\": {}, \
         \"watermarks_forwarded\": {}, \"watermark_lag_ms\": {}}}",
        wall,
        if wall > 0.0 { n as f64 / wall } else { 0.0 },
        r.metrics.late_records.load(Ordering::Relaxed),
        r.metrics.watermarks_forwarded.load(Ordering::Relaxed),
        r.metrics.watermark_lag_ms.load(Ordering::Relaxed),
    )
}

fn main() {
    let n = events();
    println!("# FlowUnits event-time benchmarks ({n} events per scenario)");

    let proc = run_proc_window(n);
    println!(
        "proc_window     {:>10.3}s  {:>14}",
        proc.wall_time.as_secs_f64(),
        flowunits::util::fmt_rate(n, proc.wall_time),
    );

    let event = run_event_window(n, false);
    assert_exact("event_window", n, &event);
    let ratio = event.wall_time.as_secs_f64() / proc.wall_time.as_secs_f64().max(1e-9);
    println!(
        "event_window    {:>10.3}s  {:>14}  ({ratio:.2}x the processing-time wall)",
        event.wall_time.as_secs_f64(),
        flowunits::util::fmt_rate(n, event.wall_time),
    );

    let hop3 = run_event_window(n, true);
    assert_exact("watermark_3hop", n, &hop3);
    let fw = hop3.metrics.watermarks_forwarded.load(Ordering::Relaxed);
    let lag = hop3.metrics.watermark_lag_ms.load(Ordering::Relaxed);
    assert!(
        fw > 0,
        "three hops with event-time panes must forward watermark frames"
    );
    println!(
        "watermark_3hop  {:>10.3}s  {:>14}  {fw} watermarks forwarded, worst lag {lag}ms",
        hop3.wall_time.as_secs_f64(),
        flowunits::util::fmt_rate(n, hop3.wall_time),
    );

    let rows = vec![
        report_row("proc_window", n, &proc),
        report_row("event_window", n, &event),
        report_row("watermark_3hop", n, &hop3),
    ];
    let json = format!(
        "{{\n  \"bench\": \"event_time\",\n  \"events\": {n},\n  \"scenarios\": [\n{}\n  ]\n}}\n",
        rows.join(",\n")
    );
    // cargo runs bench binaries with CWD = the package root (rust/);
    // EVENT_TIME_OUT overrides the destination
    let path = std::env::var("EVENT_TIME_OUT").unwrap_or_else(|_| "BENCH_event_time.json".into());
    let mut f = std::fs::File::create(&path).expect("create BENCH_event_time.json");
    f.write_all(json.as_bytes()).expect("write bench results");
    println!("\nwrote {path}");
}
