//! `cargo bench --bench columnar` — typed columnar data-plane benchmarks:
//! the monomorphized column operators against the classic `Value` path
//! they replace. Three scenario pairs:
//!
//! * **micro_columnar / micro_value** — the same `map → filter → key_by`
//!   chain driven batch-by-batch through `run_chain_data` (column
//!   batches) and `run_chain` (`Value` rows), best-of-3 interleaved. The
//!   tentpole acceptance bar: the monomorphized chain must beat the
//!   `Value` chain by **≥ 2×** at full size — and produce bit-identical
//!   outputs, key-hash column included;
//! * **col_linear / col_linear_value** — end-to-end typed `map → filter`
//!   pipeline with `JobConfig::columnar` on vs off;
//! * **col_keyed / col_keyed_value** — end-to-end typed
//!   `map → filter → key_by → fold` with the columnar hash shuffle on vs
//!   off; the collected per-key results must match exactly.
//!
//! Results land in `BENCH_columnar.json` (override with `COLUMNAR_OUT`);
//! `COLUMNAR_EVENTS` scales the workload, and CI runs a small smoke value
//! (the 2× bar is asserted only at full size — smoke runs on shared
//! runners are noise, so parity is the smoke-mode check).

use flowunits::api::{DecodeErrors, JobConfig, JobReport, PlannerKind, Source, StreamContext};
use flowunits::columnar::{ColumnBatch, Layout};
use flowunits::config::eval_cluster;
use flowunits::runtime::col_exec::{
    column_batch_of, ColumnFilterExec, ColumnKeyByExec, ColumnMapExec,
};
use flowunits::runtime::exec::{FilterExec, KeyByExec, MapExec};
use flowunits::runtime::{run_chain, run_chain_data, ChainBuffers, OpExec};
use flowunits::value::{Batch, BatchData, Value};
use std::io::Write;
use std::sync::Arc;
use std::time::{Duration, Instant};

fn events() -> u64 {
    std::env::var("COLUMNAR_EVENTS")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(500_000)
}

const BATCH: i64 = 4096;

fn col_chain() -> Vec<Box<dyn OpExec>> {
    let e = || Arc::new(DecodeErrors::default());
    vec![
        Box::new(ColumnMapExec::<i64, i64>::new(
            Arc::new(|x| x.wrapping_mul(31)),
            e(),
        )),
        Box::new(ColumnFilterExec::<i64>::new(Arc::new(|x| x % 7 != 0), e())),
        Box::new(ColumnKeyByExec::<i64, i64>::new(Arc::new(|x| x % 64), e())),
    ]
}

fn value_chain() -> Vec<Box<dyn OpExec>> {
    vec![
        Box::new(MapExec(Arc::new(|v: Value| {
            Value::I64(v.as_i64().unwrap().wrapping_mul(31))
        }))),
        Box::new(FilterExec(Arc::new(|v: &Value| {
            v.as_i64().unwrap() % 7 != 0
        }))),
        Box::new(KeyByExec(Arc::new(|v: &Value| {
            Value::I64(v.as_i64().unwrap() % 64)
        }))),
    ]
}

/// One timed pass of the columnar chain, batch generation included (the
/// columnar synthetic source builds columns natively, so generation is
/// part of what the representation buys). Returns (wall, records out).
fn time_columnar(n: i64) -> (Duration, u64) {
    let mut ops = col_chain();
    let mut bufs = ChainBuffers::new(None);
    let mut out_records = 0u64;
    let t0 = Instant::now();
    let mut lo = 0i64;
    while lo < n {
        let hi = (lo + BATCH).min(n);
        let cb = column_batch_of(&Layout::I64, lo..hi);
        match run_chain_data(&mut ops, BatchData::Columns(cb), &mut bufs) {
            BatchData::Columns(c) => out_records += c.len() as u64,
            BatchData::Rows(b) => out_records += b.values().len() as u64,
        }
        lo = hi;
    }
    (t0.elapsed(), out_records)
}

/// One timed pass of the equivalent `Value` chain.
fn time_value(n: i64) -> (Duration, u64) {
    let mut ops = value_chain();
    let mut bufs = ChainBuffers::new(None);
    let mut out_records = 0u64;
    let t0 = Instant::now();
    let mut lo = 0i64;
    while lo < n {
        let hi = (lo + BATCH).min(n);
        let mut values = Vec::with_capacity((hi - lo) as usize);
        for i in lo..hi {
            values.push(Value::I64(i));
        }
        let out = run_chain(&mut ops, Batch::new(values), &mut bufs);
        out_records += out.values().len() as u64;
        lo = hi;
    }
    (t0.elapsed(), out_records)
}

/// Feeds the full input through both chains once (untimed) and asserts
/// the outputs — values *and* the computed key-hash column — are
/// identical batch by batch.
fn assert_micro_parity(n: i64) {
    let mut col_ops = col_chain();
    let mut row_ops = value_chain();
    let mut bufs = ChainBuffers::new(None);
    let mut lo = 0i64;
    while lo < n {
        let hi = (lo + BATCH).min(n);
        let cb = column_batch_of(&Layout::I64, lo..hi);
        let got: ColumnBatch =
            match run_chain_data(&mut col_ops, BatchData::Columns(cb), &mut bufs) {
                BatchData::Columns(c) => c,
                BatchData::Rows(_) => panic!("monomorphized chain fell off the columnar path"),
            };
        let mut values = Vec::with_capacity((hi - lo) as usize);
        for i in lo..hi {
            values.push(Value::I64(i));
        }
        let expect = run_chain(&mut row_ops, Batch::new(values), &mut bufs);
        assert_eq!(
            got.to_batch().values(),
            expect.values(),
            "columnar chain diverged from the Value chain in batch [{lo}, {hi})"
        );
        assert_eq!(
            got.key_hashes().expect("columnar key_by attaches hashes"),
            expect.key_hashes().expect("row key_by attaches hashes"),
            "key-hash column diverged in batch [{lo}, {hi})"
        );
        lo = hi;
    }
}

fn config(columnar: bool) -> JobConfig {
    JobConfig {
        planner: PlannerKind::FlowUnits,
        columnar,
        ..Default::default()
    }
}

fn run_typed_linear(n: u64, columnar: bool) -> JobReport {
    let mut ctx = StreamContext::new(eval_cluster(None, Duration::ZERO), config(columnar));
    ctx.stream(Source::synthetic(n, |_, i| i as i64))
        .to_layer("edge")
        .map(|v: i64| v.wrapping_mul(31))
        .filter(|v| v % 7 != 0)
        .to_layer("cloud")
        .collect_count();
    ctx.execute().expect("col_linear pipeline")
}

fn run_typed_keyed(n: u64, columnar: bool) -> (JobReport, Vec<(i64, i64)>) {
    let mut ctx = StreamContext::new(eval_cluster(None, Duration::ZERO), config(columnar));
    let handle = ctx
        .stream(Source::synthetic(n, |_, i| i as i64))
        .to_layer("edge")
        .map(|v: i64| v.wrapping_mul(31))
        .filter(|v| v % 7 != 0)
        .to_layer("cloud")
        .key_by(|v| v % 64)
        .fold(0i64, |acc, v| *acc = acc.wrapping_add(v))
        .collect();
    let mut report = ctx.execute().expect("col_keyed pipeline");
    let mut folded: Vec<(i64, i64)> = report.take(handle).expect("keyed results");
    folded.sort_unstable();
    (report, folded)
}

fn report_row(name: &str, n: u64, r: &JobReport) -> String {
    let wall = r.wall_time.as_secs_f64();
    format!(
        "    {{\"name\": \"{name}\", \"events\": {n}, \"events_out\": {}, \
         \"wall_s\": {:.6}, \"throughput_ev_s\": {:.1}}}",
        r.events_out,
        wall,
        if wall > 0.0 { n as f64 / wall } else { 0.0 },
    )
}

fn micro_row(name: &str, n: u64, out: u64, wall: Duration) -> String {
    let w = wall.as_secs_f64();
    format!(
        "    {{\"name\": \"{name}\", \"events\": {n}, \"events_out\": {out}, \
         \"wall_s\": {:.6}, \"throughput_ev_s\": {:.1}}}",
        w,
        if w > 0.0 { n as f64 / w } else { 0.0 },
    )
}

fn main() {
    let n = events();
    let full = n >= 500_000;
    println!("# FlowUnits columnar benchmarks ({n} events per scenario)");

    // --- micro: the chain alone, both representations -----------------
    assert_micro_parity(n as i64);
    let mut best_col = (Duration::MAX, 0u64);
    let mut best_val = (Duration::MAX, 0u64);
    for _ in 0..3 {
        let c = time_columnar(n as i64);
        if c.0 < best_col.0 {
            best_col = c;
        }
        let v = time_value(n as i64);
        if v.0 < best_val.0 {
            best_val = v;
        }
    }
    assert_eq!(
        best_col.1, best_val.1,
        "both chains must keep the same record count"
    );
    let speedup = best_val.0.as_secs_f64() / best_col.0.as_secs_f64().max(1e-9);
    println!(
        "micro      columnar {:>9.3}s   value {:>9.3}s   speedup {speedup:.2}x",
        best_col.0.as_secs_f64(),
        best_val.0.as_secs_f64(),
    );
    if full {
        assert!(
            speedup >= 2.0,
            "columnar acceptance bar: the monomorphized map/filter/key_by \
             chain must beat the Value chain by >= 2x at full size, got {speedup:.2}x"
        );
    } else if speedup < 1.0 {
        // smoke measurements are milliseconds on a shared runner — report,
        // don't gate; the 2x bar is enforced at full size
        println!("note: smoke-mode speedup {speedup:.2}x (noise-prone; not gated)");
    }

    // --- end-to-end: columnar on vs off, identical results ------------
    let lin_col = run_typed_linear(n, true);
    let lin_val = run_typed_linear(n, false);
    assert_eq!(
        lin_col.events_out, lin_val.events_out,
        "columnar on/off must agree on the linear pipeline"
    );
    println!(
        "linear     columnar {:>14}   value {:>14}",
        flowunits::util::fmt_rate(n, lin_col.wall_time),
        flowunits::util::fmt_rate(n, lin_val.wall_time),
    );

    let (keyed_col, folded_col) = run_typed_keyed(n, true);
    let (keyed_val, folded_val) = run_typed_keyed(n, false);
    assert_eq!(
        folded_col, folded_val,
        "columnar on/off must produce identical per-key fold results"
    );
    println!(
        "keyed      columnar {:>14}   value {:>14}   ({} keys)",
        flowunits::util::fmt_rate(n, keyed_col.wall_time),
        flowunits::util::fmt_rate(n, keyed_val.wall_time),
        folded_col.len(),
    );

    let rows = vec![
        micro_row("micro_columnar", n, best_col.1, best_col.0),
        micro_row("micro_value", n, best_val.1, best_val.0),
        report_row("col_linear", n, &lin_col),
        report_row("col_linear_value", n, &lin_val),
        report_row("col_keyed", n, &keyed_col),
        report_row("col_keyed_value", n, &keyed_val),
    ];
    let json = format!(
        "{{\n  \"bench\": \"columnar\",\n  \"events\": {n}, \"micro_speedup\": {speedup:.3},\n  \"scenarios\": [\n{}\n  ]\n}}\n",
        rows.join(",\n")
    );
    // cargo runs bench binaries with CWD = the package root (rust/);
    // COLUMNAR_OUT overrides the destination
    let path = std::env::var("COLUMNAR_OUT").unwrap_or_else(|_| "BENCH_columnar.json".into());
    let mut f = std::fs::File::create(&path).expect("create BENCH_columnar.json");
    f.write_all(json.as_bytes()).expect("write bench results");
    println!("\nwrote {path}");
}
