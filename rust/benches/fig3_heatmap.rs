//! `cargo bench --bench fig3_heatmap` — regenerates **Fig. 3** of the
//! paper: execution-time ratio of the Renoir baseline deployment vs the
//! FlowUnits locality-aware deployment over {unlimited, 1 Gbit, 100 Mbit,
//! 10 Mbit} × {0, 10, 100 ms} inter-zone links, on the §V evaluation
//! cluster (4×1-core edges, 2×4-core site, 1×16-core cloud).
//!
//! Events per cell default to 100k (`FIG3_EVENTS` overrides; the paper
//! used 10M on a 16-core workstation). Each cell runs `FIG3_REPS` times
//! (default 3) and reports the median.

use flowunits::api::raw::{JobConfig, PlannerKind, Source, StreamContext, WindowAgg};
use flowunits::config::eval_cluster;
use flowunits::value::Value;
use std::time::Duration;

fn build_pipeline(ctx: &mut StreamContext, events: u64) {
    ctx.stream(Source::synthetic(events, |_, i| Value::I64(i as i64)))
        .to_layer("edge")
        .filter(|v| v.as_i64().unwrap() % 3 == 0) // O1
        .to_layer("site")
        .key_by(|v| Value::I64(v.as_i64().unwrap() % 16))
        .window(100, WindowAgg::Mean) // O2
        .to_layer("cloud")
        .map(|v| {
            let (_k, mean) = v.as_pair().unwrap();
            let mut n = (mean.as_f64().unwrap().abs() as u64).max(1);
            let mut steps = 0i64;
            while n != 1 {
                n = if n % 2 == 0 { n / 2 } else { 3 * n + 1 };
                steps += 1;
            }
            Value::I64(steps) // O3: Collatz convergence steps
        })
        .collect_count();
}

fn median(mut xs: Vec<f64>) -> f64 {
    xs.sort_by(|a, b| a.partial_cmp(b).unwrap());
    xs[xs.len() / 2]
}

fn run_cell(planner: PlannerKind, bw: Option<u64>, lat: Duration, events: u64, reps: usize) -> f64 {
    let times: Vec<f64> = (0..reps)
        .map(|_| {
            let mut ctx = StreamContext::new(
                eval_cluster(bw, lat),
                JobConfig {
                    planner,
                    ..Default::default()
                },
            );
            build_pipeline(&mut ctx, events);
            ctx.execute().expect("bench cell").wall_time.as_secs_f64()
        })
        .collect();
    median(times)
}

fn main() {
    let events: u64 = std::env::var("FIG3_EVENTS")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(100_000);
    let reps: usize = std::env::var("FIG3_REPS")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(3);
    let bandwidths: [(Option<u64>, &str); 4] = [
        (None, "unlimited"),
        (Some(1_000_000_000), "1Gbit"),
        (Some(100_000_000), "100Mbit"),
        (Some(10_000_000), "10Mbit"),
    ];
    let latencies = [
        (Duration::ZERO, "0ms"),
        (Duration::from_millis(10), "10ms"),
        (Duration::from_millis(100), "100ms"),
    ];
    println!("# Fig. 3 heatmap — Renoir/FlowUnits wall-time ratio");
    println!("# {events} events/cell, median of {reps} reps\n");
    println!(
        "{:<12} {:<8} {:>11} {:>13} {:>7}",
        "bandwidth", "latency", "renoir(s)", "flowunits(s)", "ratio"
    );
    let mut last_unlimited = 1.0;
    let mut monotone_ok = true;
    for (bw, bwname) in bandwidths {
        for (lat, latname) in latencies {
            let r = run_cell(PlannerKind::Renoir, bw, lat, events, reps);
            let f = run_cell(PlannerKind::FlowUnits, bw, lat, events, reps);
            let ratio = r / f;
            println!("{bwname:<12} {latname:<8} {r:>11.3} {f:>13.3} {ratio:>7.2}");
            if bw.is_none() && lat.is_zero() {
                last_unlimited = ratio;
            }
            if bw == Some(10_000_000) && lat == Duration::from_millis(100) && ratio < last_unlimited
            {
                monotone_ok = false;
            }
        }
    }
    println!(
        "\nshape check: worst-network ratio {} the unlimited ratio (paper: grows \
         as links degrade)",
        if monotone_ok { "exceeds" } else { "DOES NOT exceed" }
    );
}
