//! `cargo bench --bench recovery` — cost of the checkpoint/recovery
//! control plane.
//!
//! Three scenarios over the keyed-reduce stress shape (`source@edge →
//! filter ∥ "agg"@cloud: key_by → reduce → collect`, rate-limited
//! sources so throughput reflects a sustained steady state):
//!
//! * `checkpoint_off` — the legacy deployment, no supervisor;
//! * `checkpoint_on` — periodic coordinated checkpoints on
//!   `RECOVERY_CKPT_MS`; the paper-level claim checked in-binary is that
//!   steady-state throughput stays within 10% of `checkpoint_off`
//!   (override the threshold with `RECOVERY_RATIO_PCT`);
//! * `kill_recovery` — an instance thread is killed mid-run by an
//!   injected panic; the run must still produce exact per-key sums, and
//!   the time from the fault to the supervisor's recovery is reported
//!   as `recovery_ms` (informational, not gated).
//!
//! Results land in `BENCH_recovery.json` (override with `RECOVERY_OUT`).
//! `RECOVERY_EVENTS`, `RECOVERY_RATE` (events/second per source), and
//! `RECOVERY_REPS` scale the workload; CI runs a small smoke
//! configuration gated by the floors in `BENCH_baseline.json`.

use flowunits::api::raw::{JobConfig, PlannerKind, Source, StreamContext};
use flowunits::config::eval_cluster;
use flowunits::coordinator::Coordinator;
use flowunits::value::Value;
use std::io::Write;
use std::sync::atomic::{AtomicBool, AtomicI64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

const KEYS: i64 = 16;

fn env_u64(name: &str, default: u64) -> u64 {
    std::env::var(name)
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(default)
}

fn config(checkpoint_ms: u64) -> JobConfig {
    JobConfig {
        planner: PlannerKind::FlowUnits,
        decouple_units: true,
        batch_size: 128,
        poll_timeout: Duration::from_millis(10),
        checkpoint_interval: if checkpoint_ms > 0 {
            Some(Duration::from_millis(checkpoint_ms))
        } else {
            None
        },
        ..Default::default()
    }
}

fn graph(
    total: u64,
    rate: f64,
    cfg: &JobConfig,
    bomb: Option<Arc<AtomicI64>>,
    fired: Option<Arc<Mutex<Option<Instant>>>>,
) -> flowunits::graph::LogicalGraph {
    let mut ctx = StreamContext::new(eval_cluster(None, Duration::ZERO), cfg.clone());
    ctx.stream(Source::synthetic_rated(total, rate, |_, i| {
        Value::I64(i as i64)
    }))
    .to_layer("edge")
    .filter(|v| v.as_i64().unwrap() >= 0)
    .unit("agg")
    .to_layer("cloud")
    .map(move |v| {
        if let Some(b) = &bomb {
            if b.fetch_sub(1, Ordering::SeqCst) == 1 {
                if let Some(f) = &fired {
                    *f.lock().unwrap() = Some(Instant::now());
                }
                panic!("injected fault: bench kills this instance");
            }
        }
        v
    })
    .key_by(|v| Value::I64(v.as_i64().unwrap() % KEYS))
    .reduce(|a, b| Value::I64(a.as_i64().unwrap() + b.as_i64().unwrap()))
    .collect_vec();
    ctx.into_graph().expect("bench graph")
}

struct Outcome {
    ev_s: f64,
    checkpoints: u64,
    recoveries: u64,
    recovery_ms: f64,
}

/// One measured job. With `kill_at`, an instance panics on the
/// `kill_at`-th processed event and the fault→recovery latency is
/// sampled from the metrics.
fn run(total: u64, rate: f64, checkpoint_ms: u64, kill_at: Option<i64>) -> Outcome {
    let cfg = config(checkpoint_ms);
    let bomb = kill_at.map(|n| Arc::new(AtomicI64::new(n)));
    let fired: Option<Arc<Mutex<Option<Instant>>>> = kill_at.map(|_| Arc::new(Mutex::new(None)));
    let g = graph(total, rate, &cfg, bomb.clone(), fired.clone());
    let coord = Coordinator::new(eval_cluster(None, Duration::ZERO), cfg);
    let dep = coord.deploy(&g).expect("deploy");
    let metrics = dep.metrics();

    // watcher: timestamp the moment the supervisor's recovery lands
    let done = Arc::new(AtomicBool::new(false));
    let watcher = {
        let metrics = metrics.clone();
        let done = done.clone();
        std::thread::spawn(move || {
            while !done.load(Ordering::Relaxed) {
                if metrics.recoveries.load(Ordering::Relaxed) >= 1 {
                    return Some(Instant::now());
                }
                std::thread::sleep(Duration::from_millis(1));
            }
            None
        })
    };
    let report = dep.wait().expect("job completes");
    done.store(true, Ordering::Relaxed);
    let recovered_at = watcher.join().expect("watcher");

    // conservation: the per-key sums must add up to sum(0..total)
    // whatever checkpoints, rolls, or recoveries happened mid-run
    let got: i64 = report
        .collected
        .iter()
        .map(|v| v.as_pair().unwrap().1.as_i64().unwrap())
        .sum();
    let expect = (total as i64) * (total as i64 - 1) / 2;
    assert_eq!(got, expect, "per-key sums diverged (loss or duplication)");
    assert_eq!(report.events_in, total);

    let recovery_ms = match (fired.and_then(|f| *f.lock().unwrap()), recovered_at) {
        (Some(t0), Some(t1)) if t1 > t0 => t1.duration_since(t0).as_secs_f64() * 1000.0,
        _ => -1.0,
    };
    Outcome {
        ev_s: report.events_in as f64 / report.wall_time.as_secs_f64(),
        checkpoints: report.metrics.checkpoints_taken.load(Ordering::Relaxed),
        recoveries: report.metrics.recoveries.load(Ordering::Relaxed),
        recovery_ms,
    }
}

/// Best-of-`reps` (throughput noise on shared runners only ever slows a
/// run down, so max is the honest steady-state figure).
fn best_of(reps: u64, mut f: impl FnMut() -> Outcome) -> Outcome {
    let mut best = f();
    for _ in 1..reps {
        let o = f();
        if o.ev_s > best.ev_s {
            best = o;
        }
    }
    best
}

fn main() {
    let total = env_u64("RECOVERY_EVENTS", 300_000);
    let rate = env_u64("RECOVERY_RATE", 25_000) as f64;
    let ckpt_ms = env_u64("RECOVERY_CKPT_MS", 250);
    let reps = env_u64("RECOVERY_REPS", 2).max(1);
    let ratio_pct = env_u64("RECOVERY_RATIO_PCT", 90);
    println!(
        "# FlowUnits recovery bench ({total} events, {rate} ev/s per source, \
         checkpoint every {ckpt_ms} ms, best of {reps})"
    );

    let off = best_of(reps, || run(total, rate, 0, None));
    println!("checkpoint_off : {:>12.0} ev/s", off.ev_s);
    let on = best_of(reps, || run(total, rate, ckpt_ms, None));
    println!(
        "checkpoint_on  : {:>12.0} ev/s   ({} checkpoints)",
        on.ev_s, on.checkpoints
    );
    let kill = run(total, rate, ckpt_ms, Some((total / 2) as i64));
    println!(
        "kill_recovery  : {:>12.0} ev/s   ({} recoveries, fault→recovery {:.1} ms)",
        kill.ev_s, kill.recoveries, kill.recovery_ms
    );
    assert!(
        kill.recoveries >= 1,
        "the injected fault did not trigger a recovery"
    );

    let ratio = on.ev_s / off.ev_s;
    println!("on/off ratio   : {ratio:.3} (threshold {:.2})", ratio_pct as f64 / 100.0);
    assert!(
        ratio >= ratio_pct as f64 / 100.0,
        "checkpointing costs more than {}% of steady-state throughput \
         (off {:.0} ev/s, on {:.0} ev/s)",
        100 - ratio_pct,
        off.ev_s,
        on.ev_s
    );

    let json = format!(
        "{{\n  \"bench\": \"recovery\",\n  \"events\": {total},\n  \"rate_per_source\": {rate},\n  \
         \"checkpoint_ms\": {ckpt_ms},\n  \"on_off_ratio\": {ratio:.4},\n  \"scenarios\": [\n    \
         {{\"name\": \"checkpoint_off\", \"throughput_ev_s\": {:.1}}},\n    \
         {{\"name\": \"checkpoint_on\", \"throughput_ev_s\": {:.1}, \"checkpoints\": {}}},\n    \
         {{\"name\": \"kill_recovery\", \"throughput_ev_s\": {:.1}, \"recoveries\": {}, \
         \"recovery_ms\": {:.1}}}\n  ]\n}}\n",
        off.ev_s, on.ev_s, on.checkpoints, kill.ev_s, kill.recoveries, kill.recovery_ms,
    );
    let path = std::env::var("RECOVERY_OUT").unwrap_or_else(|_| "BENCH_recovery.json".into());
    let mut f = std::fs::File::create(&path).expect("create BENCH_recovery.json");
    f.write_all(json.as_bytes()).expect("write bench results");
    println!("\nwrote {path}");
}
