//! `cargo bench --bench durability` — bounded-memory queues under
//! sustained overload.
//!
//! Two scenarios over the keyed-reduce stress shape (`source@edge →
//! filter ∥ "agg"@cloud: map(drag) → key_by → reduce → collect`, one
//! deliberately dragging consumer instance behind an unpaced source, so
//! the queue boundary accumulates a backlog that dwarfs the budget):
//!
//! * `unbounded_resident` — durable broker, no budget: the backlog sits
//!   fully resident, and its peak measures the workload's natural
//!   memory appetite;
//! * `bounded_spill` — the same workload through a `DUR_BUDGET`-byte
//!   broker: cold records are evicted to the segment files and re-read
//!   as the consumer catches up. The in-binary claims are that the
//!   resident high-water stays flat at the budget (≥ `DUR_RATIO`x under
//!   the unbounded peak, default 4x) while output stays exact, and that
//!   spilling actually engaged (`spill_reads > 0`).
//!
//! Results land in `BENCH_durability.json` (override with `DUR_OUT`).
//! `DUR_EVENTS`, `DUR_BUDGET`, `DUR_DRAG_US`, and `DUR_REPS` scale the
//! workload; CI runs a small smoke configuration gated by the floors in
//! `BENCH_baseline.json`.

use flowunits::api::raw::{JobConfig, PlannerKind, Replication, Source, StreamContext};
use flowunits::config::eval_cluster;
use flowunits::coordinator::Coordinator;
use flowunits::value::Value;
use std::io::Write;
use std::sync::atomic::Ordering;
use std::time::Duration;

const KEYS: i64 = 16;

fn env_u64(name: &str, default: u64) -> u64 {
    std::env::var(name)
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(default)
}

fn config(dir: &std::path::Path, budget: Option<u64>) -> JobConfig {
    JobConfig {
        planner: PlannerKind::FlowUnits,
        decouple_units: true,
        batch_size: 128,
        poll_timeout: Duration::from_millis(10),
        queue_dir: Some(dir.to_path_buf()),
        queue_budget: budget,
        ..Default::default()
    }
}

struct Outcome {
    ev_s: f64,
    peak_resident: u64,
    spill_reads: u64,
    records_shed: u64,
}

/// One measured job against a fresh durable queue dir.
fn run(total: u64, budget: Option<u64>, drag: Duration, tag: &str) -> Outcome {
    let dir = std::env::temp_dir().join(format!("fu-bench-dur-{}-{tag}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    let cfg = config(&dir, budget);
    let mut ctx = StreamContext::new(eval_cluster(None, Duration::ZERO), cfg.clone());
    ctx.stream(Source::synthetic_rated(total, 1_000_000.0, |_, i| {
        Value::I64(i as i64)
    }))
    .to_layer("edge")
    .filter(|v| v.as_i64().unwrap() >= 0)
    .unit("agg")
    .to_layer("cloud")
    .replicate(Replication::Fixed(1))
    .map(move |v| {
        if !drag.is_zero() {
            std::thread::sleep(drag);
        }
        v
    })
    .key_by(|v| Value::I64(v.as_i64().unwrap() % KEYS))
    .reduce(|a, b| Value::I64(a.as_i64().unwrap() + b.as_i64().unwrap()))
    .collect_vec();
    let g = ctx.into_graph().expect("bench graph");
    let coord = Coordinator::new(eval_cluster(None, Duration::ZERO), cfg);
    let dep = coord.deploy(&g).expect("deploy");
    let report = dep.wait().expect("job completes");

    // conservation: whatever spilled and rehydrated mid-run, the per-key
    // sums must add up to sum(0..total)
    let got: i64 = report
        .collected
        .iter()
        .map(|v| v.as_pair().unwrap().1.as_i64().unwrap())
        .sum();
    let expect = (total as i64) * (total as i64 - 1) / 2;
    assert_eq!(got, expect, "per-key sums diverged (loss or duplication)");
    assert_eq!(report.events_in, total);
    let _ = std::fs::remove_dir_all(&dir);
    Outcome {
        ev_s: report.events_in as f64 / report.wall_time.as_secs_f64(),
        peak_resident: report.metrics.resident_bytes.load(Ordering::Relaxed),
        spill_reads: report.metrics.spill_reads.load(Ordering::Relaxed),
        records_shed: report.metrics.records_shed.load(Ordering::Relaxed),
    }
}

/// Best-of-`reps` by throughput; peaks are taken from the same best run
/// so the reported scenario is one coherent execution.
fn best_of(reps: u64, mut f: impl FnMut() -> Outcome) -> Outcome {
    let mut best = f();
    for _ in 1..reps {
        let o = f();
        if o.ev_s > best.ev_s {
            best = o;
        }
    }
    best
}

fn main() {
    let total = env_u64("DUR_EVENTS", 60_000);
    let budget = env_u64("DUR_BUDGET", 48 * 1024);
    let drag = Duration::from_micros(env_u64("DUR_DRAG_US", 20));
    let reps = env_u64("DUR_REPS", 2).max(1);
    let ratio_floor = env_u64("DUR_RATIO", 4) as f64;
    println!(
        "# FlowUnits durability bench ({total} events, {budget}-byte budget, \
         {}µs consumer drag, best of {reps})",
        drag.as_micros()
    );

    let unbounded = best_of(reps, || run(total, None, drag, "unbounded"));
    println!(
        "unbounded_resident : {:>12.0} ev/s   (peak resident {} bytes)",
        unbounded.ev_s, unbounded.peak_resident
    );
    let bounded = best_of(reps, || run(total, Some(budget), drag, "bounded"));
    println!(
        "bounded_spill      : {:>12.0} ev/s   (peak resident {} bytes, {} spill reads)",
        bounded.ev_s, bounded.peak_resident, bounded.spill_reads
    );

    assert!(
        bounded.spill_reads > 0,
        "the backlog never outgrew the budget — raise DUR_EVENTS or DUR_DRAG_US"
    );
    assert_eq!(
        bounded.records_shed, 0,
        "a durable bounded broker must spill, never shed"
    );
    assert!(
        bounded.peak_resident <= budget + 16 * 1024,
        "resident high-water {} blew past the {budget}-byte budget",
        bounded.peak_resident
    );
    let ratio = unbounded.peak_resident as f64 / bounded.peak_resident.max(1) as f64;
    println!("residency ratio    : {ratio:.1}x (floor {ratio_floor:.0}x)");
    assert!(
        ratio >= ratio_floor,
        "bounding the broker only cut peak residency {ratio:.1}x \
         (unbounded {} bytes, bounded {} bytes) — expected ≥ {ratio_floor:.0}x",
        unbounded.peak_resident,
        bounded.peak_resident
    );

    let json = format!(
        "{{\n  \"bench\": \"durability\",\n  \"events\": {total},\n  \
         \"budget_bytes\": {budget},\n  \"residency_ratio\": {ratio:.2},\n  \
         \"scenarios\": [\n    \
         {{\"name\": \"unbounded_resident\", \"throughput_ev_s\": {:.1}, \
         \"peak_resident_bytes\": {}}},\n    \
         {{\"name\": \"bounded_spill\", \"throughput_ev_s\": {:.1}, \
         \"peak_resident_bytes\": {}, \"spill_reads\": {}}}\n  ]\n}}\n",
        unbounded.ev_s,
        unbounded.peak_resident,
        bounded.ev_s,
        bounded.peak_resident,
        bounded.spill_reads,
    );
    let path = std::env::var("DUR_OUT").unwrap_or_else(|_| "BENCH_durability.json".into());
    let mut f = std::fs::File::create(&path).expect("create BENCH_durability.json");
    f.write_all(json.as_bytes()).expect("write bench results");
    println!("\nwrote {path}");
}
