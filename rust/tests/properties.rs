//! Cross-module property tests (in-repo harness, see `flowunits::proptest`):
//! codec round-trips, routing invariants, queue at-least-once semantics,
//! window/fold algebra, batch copy-on-write / encode-cache laws, and
//! end-to-end conservation laws.

use flowunits::api::raw::{JobConfig, PlannerKind, Source, StreamContext, WindowAgg};
use flowunits::channels::{FanOut, Inbox, OutPort, Routing, Target};
use flowunits::config::eval_cluster;
use flowunits::proptest::{forall, Gen};
use flowunits::value::{decode_batch, encode_batch, Batch, Value};
use std::sync::mpsc::sync_channel;
use std::sync::Arc;
use std::time::Duration;

fn arb_value(g: &mut Gen, depth: usize) -> Value {
    let pick = if depth == 0 {
        g.usize_in(0, 6)
    } else {
        g.usize_in(0, 8)
    };
    match pick {
        0 => Value::Null,
        1 => Value::Bool(g.bool(0.5)),
        2 => Value::I64(g.i64_in(i64::MIN / 2, i64::MAX / 2)),
        3 => Value::F64(g.f64_in(-1e12, 1e12)),
        4 => Value::Str(g.ident(24)),
        5 => {
            let n = g.usize_in(0, 8);
            Value::F32s(g.vec_of(n, |g| g.f64_in(-1e6, 1e6) as f32))
        }
        6 => Value::pair(arb_value(g, depth - 1), arb_value(g, depth - 1)),
        _ => {
            let n = g.usize_in(0, 5);
            Value::List(g.vec_of(n, |g| arb_value(g, depth - 1)))
        }
    }
}

#[test]
fn prop_codec_roundtrip() {
    forall("value codec round-trips", 500, |g| {
        let v = arb_value(g, 3);
        let enc = v.encode();
        assert_eq!(enc.len(), v.encoded_size(), "size accounting for {v:?}");
        let dec = Value::decode_exact(&enc).unwrap();
        assert_eq!(v, dec);
    });
}

#[test]
fn prop_batch_codec_roundtrip() {
    forall("batch codec round-trips", 200, |g| {
        let n = g.usize_in(0, 64);
        let batch = g.vec_of(n, |g| arb_value(g, 2));
        assert_eq!(decode_batch(&encode_batch(&batch)).unwrap(), batch);
    });
}

#[test]
fn prop_stable_hash_equals_encoding_equality() {
    forall("equal values hash equal; unequal mostly differ", 300, |g| {
        let a = arb_value(g, 2);
        let b = arb_value(g, 2);
        if a == b {
            assert_eq!(a.stable_hash(), b.stable_hash());
        }
        // same value always self-consistent
        assert_eq!(a.stable_hash(), a.clone().stable_hash());
    });
}

#[test]
fn prop_truncated_encodings_never_decode() {
    forall("truncations rejected", 150, |g| {
        let v = arb_value(g, 2);
        let enc = v.encode();
        if enc.len() > 1 {
            let cut = g.usize_in(0, enc.len() - 1);
            assert!(
                Value::decode_exact(&enc[..cut]).is_err(),
                "truncated {v:?} at {cut} decoded"
            );
        }
    });
}

#[test]
fn prop_unshared_batch_mutates_in_place() {
    forall("sole-owner batches recover their allocation", 100, |g| {
        let n = g.usize_in(1, 64);
        let values = g.vec_of(n, |g| arb_value(g, 1));
        let ptr = values.as_ptr();
        let out = Batch::new(values).into_values();
        assert_eq!(
            out.as_ptr(),
            ptr,
            "unshared batch must hand the original Vec back (pointer identity)"
        );
    });
}

#[test]
fn prop_split_sibling_never_observes_downstream_mutation() {
    // channel-level: a batch fanned out over two edges is ONE shared
    // allocation; taking and mutating it on one edge must never leak into
    // the other
    forall("split siblings are isolated", 60, |g| {
        let n = g.usize_in(1, 32);
        let original = g.vec_of(n, |g| arb_value(g, 1));
        let mk_port = |cap| {
            let (tx, rx) = sync_channel(cap);
            let port = OutPort::new(
                vec![Target::local(tx)],
                Routing::RoundRobin,
                16,
                None,
            );
            (port, rx)
        };
        let (p1, r1) = mk_port(8);
        let (p2, r2) = mk_port(8);
        let mut fan = FanOut::new(vec![p1, p2]);
        fan.send(original.clone().into());
        fan.eos();
        let a = Inbox::new(r1, 1).recv().unwrap();
        let b = Inbox::new(r2, 1).recv().unwrap();
        assert!(Batch::ptr_eq(&a, &b), "fan-out shares one allocation");
        // "mutate" downstream of edge A: take the payload and overwrite it
        let mut mine = a.into_values();
        for v in mine.iter_mut() {
            *v = Value::Null;
        }
        drop(mine);
        assert_eq!(
            b,
            original,
            "sibling edge still sees the original payload"
        );
    });
}

#[test]
fn prop_encode_cache_matches_fresh_encode_and_decodes_back() {
    forall("encode cache is canonical", 150, |g| {
        let n = g.usize_in(0, 48);
        let values = g.vec_of(n, |g| arb_value(g, 2));
        let batch = Batch::new(values.clone());
        let w1 = batch.wire();
        let w2 = batch.clone().wire();
        assert!(Arc::ptr_eq(&w1, &w2), "at most one encode per batch");
        assert_eq!(w1.as_ref(), encode_batch(&values).as_slice());
        // decode round-trip, and the decoded batch re-uses the frame bytes
        let decoded = Batch::from_wire(w1.clone()).unwrap();
        assert_eq!(decoded.values(), values.as_slice());
        let cached = decoded.wire_cached().expect("decode seeds the cache");
        assert!(Arc::ptr_eq(&cached, &w1), "no re-encode after decode");
    });
}

#[test]
fn prop_api_split_branch_mutation_is_isolated() {
    // end-to-end: one split branch rewrites every record, the other
    // collects — the collector must see the untouched originals
    forall("split branches are isolated end-to-end", 6, |g| {
        let total = g.usize_in(200, 2_000) as u64;
        let mut ctx = StreamContext::new(
            eval_cluster(None, Duration::ZERO),
            JobConfig {
                batch_size: *g.choose(&[16usize, 128]),
                ..Default::default()
            },
        );
        let s = ctx
            .stream(Source::synthetic(total, |_, i| Value::I64(i as i64)))
            .to_layer("cloud");
        let (mutator, keeper) = s.split();
        mutator
            .unit("mutator")
            .map(|_| Value::Null) // clobber every record
            .collect_count();
        keeper.unit("keeper").collect_vec();
        let report = ctx.execute().unwrap();
        let mut got: Vec<i64> = report
            .collected
            .iter()
            .map(|v| v.as_i64().expect("original I64 payload survived"))
            .collect();
        got.sort_unstable();
        assert_eq!(got, (0..total as i64).collect::<Vec<_>>());
    });
}

#[test]
fn prop_pipeline_conserves_events_across_planners_and_batches() {
    // event conservation: filter keeps exactly the matching events, no
    // matter the planner, batch size, or channel capacity
    forall("pipeline conserves events", 12, |g| {
        let planner = *g.choose(&[PlannerKind::FlowUnits, PlannerKind::Renoir]);
        let batch = *g.choose(&[7usize, 64, 513]);
        let cap = *g.choose(&[2usize, 16, 64]);
        let total = g.usize_in(1_000, 8_000) as u64;
        let modulo = g.i64_in(2, 7);
        let config = JobConfig {
            planner,
            batch_size: batch,
            channel_capacity: cap,
            ..Default::default()
        };
        let mut ctx = StreamContext::new(eval_cluster(None, Duration::ZERO), config);
        ctx.stream(Source::synthetic(total, |_, i| Value::I64(i as i64)))
            .to_layer("edge")
            .filter(move |v| v.as_i64().unwrap() % modulo == 0)
            .to_layer("cloud")
            .collect_count();
        let report = ctx.execute().unwrap();
        let expected = (0..total as i64).filter(|i| i % modulo == 0).count() as u64;
        assert_eq!(report.events_out, expected, "planner={planner:?} batch={batch} cap={cap}");
    });
}

#[test]
fn prop_keyed_fold_counts_partition_correctly() {
    // the keyed fold must count every event exactly once per key, across
    // random key cardinalities and shuffle fan-outs
    forall("keyed fold counts", 8, |g| {
        let keys = g.i64_in(1, 40);
        let total = g.usize_in(2_000, 10_000) as u64;
        let mut ctx = StreamContext::new(
            eval_cluster(None, Duration::ZERO),
            JobConfig {
                batch_size: *g.choose(&[32usize, 256]),
                ..Default::default()
            },
        );
        ctx.stream(Source::synthetic(total, |_, i| Value::I64(i as i64)))
            .to_layer("edge")
            .map(|v| v)
            .to_layer("cloud")
            .key_by(move |v| Value::I64(v.as_i64().unwrap() % keys))
            .fold(Value::I64(0), |acc, _| {
                *acc = Value::I64(acc.as_i64().unwrap() + 1)
            })
            .collect_vec();
        let report = ctx.execute().unwrap();
        assert_eq!(report.collected.len() as i64, keys.min(total as i64));
        let sum: i64 = report
            .collected
            .iter()
            .map(|v| v.as_pair().unwrap().1.as_i64().unwrap())
            .sum();
        assert_eq!(sum as u64, total);
    });
}

#[test]
fn prop_window_emission_counts() {
    // tumbling windows: emitted full windows + flush partials must cover
    // every event exactly once (verified via Count aggregate sums)
    forall("window coverage", 8, |g| {
        let size = g.usize_in(2, 200);
        let keys = g.i64_in(1, 9);
        let total = g.usize_in(500, 6_000) as u64;
        let mut ctx = StreamContext::new(eval_cluster(None, Duration::ZERO), JobConfig::default());
        ctx.stream(Source::synthetic(total, |_, i| Value::I64(i as i64)))
            .to_layer("edge")
            .map(|v| v)
            .to_layer("site")
            .key_by(move |v| Value::I64(v.as_i64().unwrap() % keys))
            .window(size, WindowAgg::Count)
            .to_layer("cloud")
            .collect_vec();
        let report = ctx.execute().unwrap();
        let covered: i64 = report
            .collected
            .iter()
            .map(|v| v.as_pair().unwrap().1.as_i64().unwrap())
            .sum();
        assert_eq!(covered as u64, total, "size={size} keys={keys}");
    });
}

#[test]
fn prop_queue_decoupling_preserves_results() {
    // queue transport must be observationally equivalent to direct links
    forall("queue equivalence", 6, |g| {
        let total = g.usize_in(1_000, 5_000) as u64;
        let modulo = g.i64_in(2, 5);
        let mut outs = Vec::new();
        for decouple in [false, true] {
            let config = JobConfig {
                decouple_units: decouple,
                poll_timeout: Duration::from_millis(5),
                batch_size: 64,
                ..Default::default()
            };
            let mut ctx = StreamContext::new(eval_cluster(None, Duration::ZERO), config);
            ctx.stream(Source::synthetic(total, |_, i| Value::I64(i as i64)))
                .to_layer("edge")
                .filter(move |v| v.as_i64().unwrap() % modulo == 0)
                .to_layer("cloud")
                .collect_vec();
            let report = ctx.execute().unwrap();
            let mut vals: Vec<i64> =
                report.collected.iter().map(|v| v.as_i64().unwrap()).collect();
            vals.sort_unstable();
            outs.push(vals);
        }
        assert_eq!(outs[0], outs[1]);
    });
}

#[test]
fn prop_constraint_eval_agrees_with_display_parse() {
    use flowunits::topology::{CapValue, Capabilities, ConstraintExpr};
    forall("constraint display/parse/eval agreement", 200, |g| {
        // random capability profile
        let mut caps = Capabilities::default();
        let names = ["n_cpu", "gpu", "memory", "arch"];
        for name in names {
            if g.bool(0.8) {
                let v = match g.usize_in(0, 3) {
                    0 => CapValue::Int(g.i64_in(0, 128)),
                    1 => CapValue::Bool(g.bool(0.5)),
                    _ => CapValue::Str(g.ident(6)),
                };
                caps.set(name, v);
            }
        }
        // random conjunction
        let n = g.usize_in(1, 4);
        let preds: Vec<String> = (0..n)
            .map(|_| {
                let attr = *g.choose(&names);
                let op = *g.choose(&["=", "!=", ">=", "<", ">"]);
                let val = match g.usize_in(0, 3) {
                    0 => g.i64_in(0, 128).to_string(),
                    1 => (*g.choose(&["yes", "no"])).to_string(),
                    _ => g.ident(6),
                };
                format!("{attr} {op} {val}")
            })
            .collect();
        let text = preds.join(" && ");
        let e1 = ConstraintExpr::parse(&text).unwrap();
        let e2 = ConstraintExpr::parse(&e1.to_string()).unwrap();
        assert_eq!(e1, e2, "display/parse round-trip of '{text}'");
        assert_eq!(e1.eval(&caps), e2.eval(&caps));
    });
}
