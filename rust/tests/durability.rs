//! Integration tests of bounded-memory queues under sustained overload:
//! a slow consumer falls far behind a fast source while the broker's
//! resident-byte budget stays an order of magnitude below the data
//! volume. Durable brokers spill cold history to their segment files and
//! transparently re-read it on demand (zero loss); bounded in-memory
//! brokers either block producers (`Backpressure`, zero loss) or shed
//! with every dropped record counted (`Shed` — loss is never silent).

use flowunits::api::raw::{JobConfig, PlannerKind, Replication, Source, StreamContext};
use flowunits::config::eval_cluster;
use flowunits::coordinator::{Coordinator, JobReport};
use flowunits::queue::{OverloadPolicy, ShedMode};
use flowunits::value::Value;
use std::collections::BTreeMap;
use std::sync::atomic::Ordering;
use std::time::Duration;

fn bounded_config(budget: u64, policy: OverloadPolicy) -> JobConfig {
    JobConfig {
        planner: PlannerKind::FlowUnits,
        decouple_units: true,
        batch_size: 32,
        poll_timeout: Duration::from_millis(10),
        queue_budget: Some(budget),
        overload_policy: policy,
        ..Default::default()
    }
}

/// `source@edge → filter ∥ "agg"@cloud: map(drag) → key_by % keys →
/// reduce(sum) → collect`. A single dragging consumer instance behind an
/// effectively unpaced source, so the queue boundary accumulates a
/// backlog that dwarfs the broker budget.
fn drag_sum_graph(
    total: u64,
    keys: i64,
    config: &JobConfig,
    drag: Duration,
) -> flowunits::graph::LogicalGraph {
    let mut ctx = StreamContext::new(eval_cluster(None, Duration::ZERO), config.clone());
    ctx.stream(Source::synthetic_rated(total, 400_000.0, |_, i| {
        Value::I64(i as i64)
    }))
    .to_layer("edge")
    .filter(|v| v.as_i64().unwrap() >= 0)
    .unit("agg")
    .to_layer("cloud")
    .replicate(Replication::Fixed(1))
    .map(move |v| {
        if !drag.is_zero() {
            std::thread::sleep(drag);
        }
        v
    })
    .key_by(move |v| Value::I64(v.as_i64().unwrap() % keys))
    .reduce(|a, b| Value::I64(a.as_i64().unwrap() + b.as_i64().unwrap()))
    .collect_vec();
    ctx.into_graph().unwrap()
}

fn run_graph(g: &flowunits::graph::LogicalGraph, config: JobConfig) -> JobReport {
    let coord = Coordinator::new(eval_cluster(None, Duration::ZERO), config);
    let dep = coord.deploy(g).unwrap();
    dep.wait().unwrap()
}

fn sorted_sums(report: &JobReport) -> Vec<(i64, i64)> {
    let mut got: Vec<(i64, i64)> = report
        .collected
        .iter()
        .map(|v| {
            let (k, x) = v.as_pair().unwrap();
            (k.as_i64().unwrap(), x.as_i64().unwrap())
        })
        .collect();
    got.sort_unstable();
    got
}

fn expected_sums(total: u64, keys: i64) -> Vec<(i64, i64)> {
    let mut sums: BTreeMap<i64, i64> = BTreeMap::new();
    for i in 0..total as i64 {
        *sums.entry(i % keys).or_insert(0) += i;
    }
    sums.into_iter().collect()
}

#[test]
fn durable_bounded_broker_spills_under_overload_with_zero_loss() {
    // ~240 KiB flow through a 16 KiB budget (15x): the durable broker
    // must evict cold records to its segment files, re-read them as the
    // dragging consumer catches up, and lose nothing.
    let dir = std::env::temp_dir().join(format!("fu-dur-spill-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    let budget = 16 * 1024;
    let (total, keys) = (24_000u64, 8i64);
    let mut config = bounded_config(budget, OverloadPolicy::default());
    config.queue_dir = Some(dir.clone());
    let g = drag_sum_graph(total, keys, &config, Duration::from_micros(30));
    let report = run_graph(&g, config);
    assert_eq!(report.events_in, total);
    assert_eq!(
        sorted_sums(&report),
        expected_sums(total, keys),
        "spill-and-rehydrate is invisible in the output"
    );
    assert!(
        report.metrics.spill_reads.load(Ordering::Relaxed) > 0,
        "the backlog outgrew the budget, so some records were re-read from segments"
    );
    assert_eq!(
        report.metrics.records_shed.load(Ordering::Relaxed),
        0,
        "durable brokers never shed — they spill"
    );
    // `resident_bytes` records the high-water mark; it must track the
    // budget (plus one in-flight record of slack), not the data volume
    let peak = report.metrics.resident_bytes.load(Ordering::Relaxed);
    assert!(
        peak <= budget + 8 * 1024,
        "resident high-water {peak} blew past the {budget}-byte budget"
    );
    std::fs::remove_dir_all(&dir).unwrap();
}

#[test]
fn bounded_in_memory_backpressure_delivers_everything() {
    // ~10x the budget flows through an in-memory broker: producers block
    // at the budget line until the consumer frees committed records, and
    // every event still arrives exactly once.
    let budget = 24 * 1024;
    let (total, keys) = (24_000u64, 8i64);
    let config = bounded_config(budget, OverloadPolicy::default());
    let g = drag_sum_graph(total, keys, &config, Duration::from_micros(20));
    let report = run_graph(&g, config);
    assert_eq!(report.events_in, total);
    assert_eq!(
        sorted_sums(&report),
        expected_sums(total, keys),
        "backpressure trades latency for completeness — zero loss"
    );
    assert_eq!(report.metrics.records_shed.load(Ordering::Relaxed), 0);
    let peak = report.metrics.resident_bytes.load(Ordering::Relaxed);
    assert!(
        peak <= budget + 8 * 1024,
        "resident high-water {peak} blew past the {budget}-byte budget"
    );
}

#[test]
fn shed_policy_counts_every_dropped_record() {
    // DropOldest under heavy overload: delivery is incomplete by design,
    // but `records_shed` must cover every missing event — loss is never
    // silent. `batch_size: 1` makes one queue record carry exactly one
    // event, so the record counter and the event ledger line up.
    let (total, budget) = (6_000u64, 8 * 1024u64);
    let mut config = bounded_config(budget, OverloadPolicy::Shed(ShedMode::DropOldest));
    config.batch_size = 1;
    // count the survivors: every event maps to 1 under a single key, so
    // the lone collected pair is (0, delivered)
    let mut ctx = StreamContext::new(eval_cluster(None, Duration::ZERO), config.clone());
    ctx.stream(Source::synthetic_rated(total, 400_000.0, |_, i| {
        Value::I64(i as i64)
    }))
    .to_layer("edge")
    .filter(|v| v.as_i64().unwrap() >= 0)
    .unit("agg")
    .to_layer("cloud")
    .replicate(Replication::Fixed(1))
    .map(|_| {
        std::thread::sleep(Duration::from_micros(150));
        Value::I64(1)
    })
    .key_by(|_| Value::I64(0))
    .reduce(|a, b| Value::I64(a.as_i64().unwrap() + b.as_i64().unwrap()))
    .collect_vec();
    let g = ctx.into_graph().unwrap();
    let report = run_graph(&g, config);
    let delivered = match sorted_sums(&report).as_slice() {
        [(0, n)] => *n as u64,
        [] => 0,
        other => panic!("unexpected collected shape: {other:?}"),
    };
    let shed = report.metrics.records_shed.load(Ordering::Relaxed);
    assert!(shed > 0, "overload must actually shed (delivered={delivered})");
    assert!(
        delivered < total,
        "with shedding engaged, delivery is incomplete by design"
    );
    // the in-flight poll window may count a record both delivered and
    // shed, so the ledger is an upper bound — but nothing disappears
    // without being counted
    assert!(
        total - delivered <= shed,
        "{} events vanished but only {shed} were accounted as shed",
        total - delivered
    );
    let peak = report.metrics.resident_bytes.load(Ordering::Relaxed);
    assert!(
        peak <= budget + 8 * 1024,
        "resident high-water {peak} blew past the {budget}-byte budget"
    );
}
