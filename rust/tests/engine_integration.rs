//! Integration tests over the whole engine: multi-unit pipelines on the
//! paper's evaluation cluster, both planners, direct and queue-decoupled
//! boundaries, shaped links, and result equivalence between deployments.

use flowunits::api::raw::{JobConfig, PlannerKind, Source, StreamContext, WindowAgg};
use flowunits::config::{eval_cluster, fig2_cluster};
use flowunits::netsim::LinkSpec;
use flowunits::value::Value;
use std::time::Duration;

fn eval_pipeline(ctx: &mut StreamContext, events: u64) {
    ctx.stream(Source::synthetic(events, |_, i| Value::I64(i as i64)))
        .to_layer("edge")
        .filter(|v| v.as_i64().unwrap() % 3 == 0)
        .to_layer("site")
        .key_by(|v| Value::I64(v.as_i64().unwrap() % 16))
        .window(100, WindowAgg::Mean)
        .to_layer("cloud")
        .map(|v| {
            let (_k, mean) = v.as_pair().unwrap();
            let mut n = (mean.as_f64().unwrap().abs() as u64).max(1);
            let mut steps = 0i64;
            while n != 1 {
                n = if n % 2 == 0 { n / 2 } else { 3 * n + 1 };
                steps += 1;
            }
            Value::I64(steps)
        })
        .collect_count();
}

#[test]
fn planners_agree_on_results() {
    let mut outs = Vec::new();
    for planner in [PlannerKind::FlowUnits, PlannerKind::Renoir] {
        let mut ctx = StreamContext::new(
            eval_cluster(None, Duration::ZERO),
            JobConfig {
                planner,
                ..Default::default()
            },
        );
        eval_pipeline(&mut ctx, 48_000);
        let report = ctx.execute().unwrap();
        assert_eq!(report.events_in, 48_000, "{planner:?}");
        outs.push(report.events_out);
    }
    // 48000/3 = 16000 filtered events; 16 keys × 1000 events = 10 full
    // windows per key + no partials ⇒ identical window counts
    assert_eq!(outs[0], outs[1]);
    assert_eq!(outs[0], 160);
}

#[test]
fn shaped_links_slow_renoir_more_than_flowunits() {
    let spec = LinkSpec {
        bandwidth_bps: Some(20_000_000),
        latency: Duration::from_millis(5),
    };
    let mut walls = Vec::new();
    for planner in [PlannerKind::Renoir, PlannerKind::FlowUnits] {
        let mut ctx = StreamContext::new(
            eval_cluster(spec.bandwidth_bps, spec.latency),
            JobConfig {
                planner,
                ..Default::default()
            },
        );
        eval_pipeline(&mut ctx, 60_000);
        let report = ctx.execute().unwrap();
        walls.push(report.wall_time.as_secs_f64());
    }
    assert!(
        walls[0] > walls[1],
        "renoir {}s should be slower than flowunits {}s on degraded links",
        walls[0],
        walls[1]
    );
}

#[test]
fn flowunits_crosses_fewer_zone_boundaries() {
    let mut crossings = Vec::new();
    for planner in [PlannerKind::Renoir, PlannerKind::FlowUnits] {
        let mut ctx = StreamContext::new(
            eval_cluster(None, Duration::ZERO),
            JobConfig {
                planner,
                ..Default::default()
            },
        );
        eval_pipeline(&mut ctx, 30_000);
        let report = ctx.execute().unwrap();
        crossings.push(report.zone_crossings);
    }
    assert!(
        crossings[0] > 2 * crossings[1],
        "renoir crossings {} should dwarf flowunits {}",
        crossings[0],
        crossings[1]
    );
}

#[test]
fn partial_locations_restrict_sources() {
    let mut ctx = StreamContext::new(
        fig2_cluster(),
        JobConfig {
            planner: PlannerKind::FlowUnits,
            locations: vec!["L1".into(), "L4".into()],
            ..Default::default()
        },
    );
    eval_pipeline(&mut ctx, 10_000);
    let report = ctx.execute().unwrap();
    assert_eq!(report.events_in, 10_000);
    // plan lists only E1 and E4 at the edge
    assert!(report.plan_description.contains("E1×1"));
    assert!(report.plan_description.contains("E4×1"));
    assert!(!report.plan_description.contains("E2"));
}

#[test]
fn durable_queue_boundaries_survive_and_count() {
    let dir = std::env::temp_dir().join(format!("fu-int-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    let config = JobConfig {
        planner: PlannerKind::FlowUnits,
        decouple_units: true,
        queue_dir: Some(dir.clone()),
        poll_timeout: Duration::from_millis(10),
        ..Default::default()
    };
    let mut ctx = StreamContext::new(eval_cluster(None, Duration::ZERO), config);
    ctx.stream(Source::synthetic(5_000, |_, i| Value::I64(i as i64)))
        .to_layer("edge")
        .filter(|v| v.as_i64().unwrap() % 2 == 0)
        .to_layer("cloud")
        .collect_count();
    let report = ctx.execute().unwrap();
    assert_eq!(report.events_out, 2_500);
    // segments exist on disk
    let segments: Vec<_> = std::fs::read_dir(&dir).unwrap().collect();
    assert!(!segments.is_empty(), "durable queue wrote segment files");
    std::fs::remove_dir_all(&dir).unwrap();
}

#[test]
fn collected_values_complete_under_shuffle() {
    // keyed fold across a multi-zone deployment must count every event
    // exactly once despite hash repartitioning across hosts
    let mut ctx = StreamContext::new(
        eval_cluster(None, Duration::ZERO),
        JobConfig::default(),
    );
    ctx.stream(Source::synthetic(9_000, |_, i| Value::I64(i as i64)))
        .to_layer("edge")
        .map(|v| v)
        .to_layer("cloud")
        .key_by(|v| Value::I64(v.as_i64().unwrap() % 7))
        .fold(Value::I64(0), |acc, _| {
            *acc = Value::I64(acc.as_i64().unwrap() + 1)
        })
        .collect_vec();
    let report = ctx.execute().unwrap();
    let total: i64 = report
        .collected
        .iter()
        .map(|v| v.as_pair().unwrap().1.as_i64().unwrap())
        .sum();
    assert_eq!(total, 9_000);
    // 7 keys, each folded on exactly one instance ⇒ exactly 7 outputs
    assert_eq!(report.collected.len(), 7);
}

#[test]
fn renoir_planner_with_constraint_still_respects_capabilities() {
    // even the baseline planner may not place a constrained FlowUnit on an
    // incapable host (matches Renoir semantics extended with constraints);
    // the constraint scopes to the dedicated "ml" unit, not the whole edge
    let mut ctx = StreamContext::new(
        fig2_cluster(),
        JobConfig {
            planner: PlannerKind::Renoir,
            ..Default::default()
        },
    );
    ctx.stream(Source::synthetic(100, |_, i| Value::I64(i as i64)))
        .to_layer("edge")
        .inspect(|_| {})
        .unit("ml")
        .add_constraint("gpu = yes")
        .map(|v| v)
        .to_layer("cloud")
        .collect_count();
    let report = ctx.execute().unwrap();
    assert_eq!(report.events_out, 100);
    // the constrained stage must appear only on C1 (the gpu host's zone)
    let line = report
        .plan_description
        .lines()
        .find(|l| l.contains("[map]"))
        .unwrap()
        .to_string();
    assert!(line.contains("C1×8"), "constrained map on gpu cores only: {line}");
    assert!(!line.contains("E1"), "no edge placement for gpu op: {line}");
}

#[test]
fn union_and_split_dag_end_to_end() {
    // two edge sources -> union at the cloud -> split into two sinks
    let mut ctx = StreamContext::new(eval_cluster(None, Duration::ZERO), JobConfig::default());
    let north = ctx
        .stream(Source::synthetic(1200, |_, i| Value::I64(i as i64)))
        .unit("north")
        .to_layer("edge");
    let south = ctx
        .stream(Source::synthetic(800, |_, i| Value::I64(1_000_000 + i as i64)))
        .unit("south")
        .to_layer("edge");
    let merged = north
        .union(south)
        .unit("merge")
        .to_layer("cloud")
        .map(|v| v);
    let (evens, all) = merged.split();
    evens
        .unit("evens")
        .filter(|v| v.as_i64().unwrap() % 2 == 0)
        .collect_vec();
    all.unit("tally").collect_count();
    let report = ctx.execute().unwrap();
    assert_eq!(report.events_in, 2000, "both sources produced");
    // both split branches saw all 2000 events: 1000 evens + 2000 counted
    assert_eq!(report.collected.len(), 1000);
    assert_eq!(report.events_out, 3000);
}

#[test]
fn union_split_results_survive_queue_decoupling() {
    let config = JobConfig {
        decouple_units: true,
        poll_timeout: Duration::from_millis(10),
        batch_size: 64,
        ..Default::default()
    };
    let mut ctx = StreamContext::new(eval_cluster(None, Duration::ZERO), config);
    let a = ctx
        .stream(Source::synthetic(600, |_, i| Value::I64(i as i64)))
        .unit("a")
        .to_layer("edge");
    let b = ctx
        .stream(Source::synthetic(400, |_, i| Value::I64(i as i64)))
        .unit("b")
        .to_layer("edge");
    let m = a.union(b).unit("m").to_layer("cloud").map(|v| v);
    let (x, y) = m.split();
    x.unit("x").collect_count();
    y.unit("y").collect_count();
    let report = ctx.execute().unwrap();
    assert_eq!(report.events_in, 1000);
    assert_eq!(report.events_out, 2000, "each branch counted every event");
}

#[test]
fn builder_errors_propagate_to_execute_instead_of_panicking() {
    let mut ctx = StreamContext::new(eval_cluster(None, Duration::ZERO), JobConfig::default());
    ctx.stream(Source::synthetic(10, |_, i| Value::I64(i as i64)))
        .to_layer("edge")
        .add_constraint("gpu >") // malformed: recorded, not panicked
        .collect_count();
    let err = ctx.execute().unwrap_err();
    assert!(
        matches!(err, flowunits::error::Error::Graph(_)),
        "builder error surfaces as Error::Graph, got: {err}"
    );
}

#[test]
fn backpressure_bounds_total_memory() {
    // a slow sink (10 Mbit bottleneck into the cloud) must not let sources
    // run unboundedly ahead; we can't measure memory portably, but we can
    // verify the job completes with bounded channels and tiny batches.
    let mut ctx = StreamContext::new(
        eval_cluster(Some(10_000_000), Duration::ZERO),
        JobConfig {
            channel_capacity: 4,
            batch_size: 64,
            ..Default::default()
        },
    );
    eval_pipeline(&mut ctx, 20_000);
    let report = ctx.execute().unwrap();
    assert_eq!(report.events_in, 20_000);
}

#[test]
fn missing_artifact_fails_deploy_cleanly() {
    let mut ctx = StreamContext::new(
        eval_cluster(None, Duration::ZERO),
        JobConfig::default(),
    );
    ctx.stream(Source::synthetic(100, |_, _| Value::F32s(vec![0.0; 5])))
        .to_layer("cloud")
        .xla_map("no-such-artifact", 8, 5)
        .collect_count();
    let err = ctx.execute();
    assert!(err.is_err(), "deploy must fail before any thread spawns");
    let msg = err.err().unwrap().to_string();
    assert!(msg.contains("make artifacts"), "actionable error: {msg}");
}

#[test]
fn example_cluster_file_parses_and_plans() {
    let spec = flowunits::config::ClusterSpec::load("examples/cluster.fu").unwrap();
    assert_eq!(spec.topology.layers, vec!["edge", "site", "cloud"]);
    assert_eq!(spec.topology.zones.len(), 8);
    let mut ctx = StreamContext::new(
        spec,
        JobConfig {
            locations: vec!["L1".into(), "L5".into()],
            ..Default::default()
        },
    );
    ctx.stream(Source::synthetic(1000, |_, i| Value::I64(i as i64)))
        .to_layer("edge")
        .filter(|v| v.as_i64().unwrap() % 2 == 0)
        .to_layer("cloud")
        .collect_count();
    let report = ctx.execute().unwrap();
    assert_eq!(report.events_out, 500);
}

#[test]
fn empty_source_completes_with_zero_output() {
    let mut ctx = StreamContext::new(eval_cluster(None, Duration::ZERO), JobConfig::default());
    ctx.stream(Source::synthetic(0, |_, i| Value::I64(i as i64)))
        .to_layer("edge")
        .map(|v| v)
        .to_layer("cloud")
        .key_by(|v| v.clone())
        .fold(Value::I64(0), |_, _| {})
        .collect_vec();
    let report = ctx.execute().unwrap();
    assert_eq!(report.events_in, 0);
    assert!(report.collected.is_empty());
}

#[test]
fn single_event_survives_all_stages() {
    let mut ctx = StreamContext::new(eval_cluster(None, Duration::ZERO), JobConfig::default());
    ctx.stream(Source::synthetic(1, |_, _| Value::F64(42.0)))
        .to_layer("edge")
        .filter(|_| true)
        .to_layer("site")
        .key_by(|_| Value::I64(0))
        .window(100, WindowAgg::Mean) // partial window flushes at EOS
        .to_layer("cloud")
        .collect_vec();
    let report = ctx.execute().unwrap();
    assert_eq!(report.collected.len(), 1);
    assert_eq!(
        report.collected[0].as_pair().unwrap().1.as_f64().unwrap(),
        42.0
    );
}

#[test]
fn stop_sources_terminates_unbounded_job() {
    let coord = flowunits::coordinator::Coordinator::new(
        eval_cluster(None, Duration::ZERO),
        JobConfig::default(),
    );
    let mut ctx = StreamContext::new(eval_cluster(None, Duration::ZERO), JobConfig::default());
    ctx.stream(Source::synthetic_rated(u64::MAX / 2, 50_000.0, |_, i| {
        Value::I64(i as i64)
    }))
    .to_layer("edge")
    .map(|v| v)
    .to_layer("cloud")
    .collect_count();
    let g = ctx.into_graph().unwrap();
    let dep = coord.deploy(&g).unwrap();
    std::thread::sleep(Duration::from_millis(150));
    dep.stop_sources();
    let report = dep.wait().unwrap();
    assert!(report.events_in > 0);
    assert_eq!(report.events_in, report.events_out);
}

#[test]
fn user_closure_panic_is_surfaced_not_hung() {
    // a panicking operator must fail the job with an error, not deadlock
    let mut ctx = StreamContext::new(eval_cluster(None, Duration::ZERO), JobConfig::default());
    ctx.stream(Source::synthetic(1_000, |_, i| Value::I64(i as i64)))
        .to_layer("edge")
        .map(|v| {
            if v.as_i64().unwrap() == 500 {
                panic!("injected operator fault");
            }
            v
        })
        .to_layer("cloud")
        .collect_count();
    let result = ctx.execute();
    assert!(result.is_err(), "panicked instance must surface as an error");
    assert!(result
        .err()
        .unwrap()
        .to_string()
        .contains("instance thread panicked"));
}

#[test]
fn zero_producer_inbox_terminates() {
    // a location subset can leave some site-zone instances with zero
    // producers; they must still terminate and propagate EOS
    let mut ctx = StreamContext::new(
        fig2_cluster(),
        JobConfig {
            locations: vec!["L1".into()], // only S1's branch is fed
            ..Default::default()
        },
    );
    ctx.stream(Source::synthetic(1_000, |_, i| Value::I64(i as i64)))
        .to_layer("edge")
        .map(|v| v)
        .to_layer("site")
        .key_by(|v| Value::I64(v.as_i64().unwrap() % 4))
        .window(10, WindowAgg::Count)
        .to_layer("cloud")
        .collect_vec();
    let report = ctx.execute().unwrap();
    let covered: i64 = report
        .collected
        .iter()
        .map(|v| v.as_pair().unwrap().1.as_i64().unwrap())
        .sum();
    assert_eq!(covered, 1_000);
}
