//! Property tests for the typed columnar data plane (in-repo harness,
//! see `flowunits::proptest`):
//!
//! * end-to-end parity — every typed operator chain shape
//!   (`map`/`filter`/`filter_map`, `key_by → fold`, `key_by → reduce`,
//!   `key_by → window`, and a mixed chain crossing the columnar/`Value`
//!   boundary) produces identical results with
//!   [`JobConfig::columnar`] on and off, under both planners;
//! * representation laws — `StreamData` column round-trips (including
//!   empty batches), row materialization vs `into_value`, the
//!   `hash_row`/`stable_hash` agreement the columnar shuffle relies on,
//!   and the wire-format equivalence that lets column batches cross
//!   process boundaries unchanged.

use flowunits::api::{JobConfig, PlannerKind, Source, StreamContext, WindowAgg};
use flowunits::channels::route_hash;
use flowunits::columnar::{ColumnBatch, Layout};
use flowunits::config::eval_cluster;
use flowunits::proptest::{forall, Gen};
use flowunits::value::StreamData;
use std::time::Duration;

fn cfg(planner: PlannerKind, columnar: bool) -> JobConfig {
    JobConfig {
        planner,
        columnar,
        ..Default::default()
    }
}

fn planner(g: &mut Gen) -> PlannerKind {
    if g.bool(0.5) {
        PlannerKind::FlowUnits
    } else {
        PlannerKind::Renoir
    }
}

#[test]
fn prop_typed_linear_chain_columnar_parity() {
    forall("map/filter/filter_map: columnar == value", 12, |g| {
        let n = g.usize_in(0, 300) as u64;
        let m = g.i64_in(1, 50);
        let p = g.i64_in(2, 9);
        let pl = planner(g);
        let run = |columnar: bool| -> Vec<i64> {
            let mut ctx =
                StreamContext::new(eval_cluster(None, Duration::ZERO), cfg(pl, columnar));
            let h = ctx
                .stream(Source::synthetic(n, |_, i| i as i64))
                .to_layer("edge")
                .map(move |v: i64| v.wrapping_mul(m))
                .filter(move |v| v % p != 0)
                .filter_map(|v| if v % 2 == 0 { Some(v / 2) } else { None })
                .to_layer("cloud")
                .collect();
            let mut report = ctx.execute().expect("linear chain");
            let mut out: Vec<i64> = report.take(h).expect("collect");
            out.sort_unstable();
            out
        };
        assert_eq!(run(true), run(false));
    });
}

#[test]
fn prop_typed_keyed_fold_columnar_parity() {
    forall("tuple key_by → fold: columnar == value", 10, |g| {
        let n = g.usize_in(0, 300) as u64;
        let k = g.i64_in(1, 17);
        let pl = planner(g);
        let run = |columnar: bool| -> Vec<(i64, i64)> {
            let mut ctx =
                StreamContext::new(eval_cluster(None, Duration::ZERO), cfg(pl, columnar));
            let h = ctx
                .stream(Source::synthetic(n, |_, i| {
                    (i as i64, (i as i64).wrapping_mul(7))
                }))
                .to_layer("edge")
                .to_layer("cloud")
                .key_by(move |t: &(i64, i64)| t.0 % k)
                .fold(0i64, |acc, t| *acc = acc.wrapping_add(t.1))
                .collect();
            let mut report = ctx.execute().expect("keyed fold");
            let mut out: Vec<(i64, i64)> = report.take(h).expect("collect");
            out.sort_unstable();
            out
        };
        assert_eq!(run(true), run(false));
    });
}

#[test]
fn prop_typed_string_keyed_reduce_columnar_parity() {
    forall("string key_by → reduce: columnar == value", 8, |g| {
        let n = g.usize_in(0, 250) as u64;
        let k = g.usize_in(1, 12) as u64;
        let pl = planner(g);
        let run = |columnar: bool| -> Vec<(String, (String, i64))> {
            let mut ctx =
                StreamContext::new(eval_cluster(None, Duration::ZERO), cfg(pl, columnar));
            let h = ctx
                .stream(Source::synthetic(n, move |_, i| {
                    (format!("sensor-{:03}", i % k), i as i64)
                }))
                .to_layer("edge")
                .to_layer("cloud")
                .key_by(|t: &(String, i64)| t.0.clone())
                .reduce(|a, b| if a.1 >= b.1 { a.clone() } else { b.clone() })
                .collect();
            let mut report = ctx.execute().expect("keyed reduce");
            let mut out: Vec<(String, (String, i64))> = report.take(h).expect("collect");
            out.sort();
            out
        };
        assert_eq!(run(true), run(false));
    });
}

#[test]
fn prop_typed_window_columnar_parity() {
    forall("key_by → sliding_window: columnar == value", 8, |g| {
        let n = g.usize_in(0, 400) as u64;
        let k = g.i64_in(1, 9);
        let size = g.usize_in(1, 20);
        let slide = g.usize_in(1, size + 1);
        let pl = planner(g);
        let run = |columnar: bool| -> Vec<(i64, i64)> {
            let mut ctx =
                StreamContext::new(eval_cluster(None, Duration::ZERO), cfg(pl, columnar));
            let h = ctx
                .stream(Source::synthetic(n, |_, i| i as i64))
                .to_layer("edge")
                .to_layer("cloud")
                .key_by(move |v: &i64| v % k)
                .sliding_window::<i64>(size, slide, WindowAgg::Count)
                .collect();
            let mut report = ctx.execute().expect("keyed window");
            let mut out: Vec<(i64, i64)> = report.take(h).expect("collect");
            out.sort_unstable();
            out
        };
        assert_eq!(run(true), run(false));
    });
}

#[test]
fn prop_mixed_chain_crossing_the_fallback_boundary() {
    // `map_values` has no columnar form: the chain runs columnar up to
    // `key_by`, falls back to `Value` rows through `map_values`, and the
    // columnar window executor then consumes rows on its row path — the
    // full representation-switch spectrum in one pipeline. Window
    // *membership* per key depends on cross-instance arrival order, so
    // the comparison is over order-independent per-key aggregates: the
    // window count and the total of the window sums (values are exact
    // binary halves, so f64 addition order cannot perturb the total).
    forall("columnar → fallback → columnar-op rows", 8, |g| {
        let n = g.usize_in(0, 400) as u64;
        let k = g.i64_in(1, 7);
        let size = g.usize_in(1, 16);
        let pl = planner(g);
        let run = |columnar: bool| -> Vec<(i64, usize, u64)> {
            let mut ctx =
                StreamContext::new(eval_cluster(None, Duration::ZERO), cfg(pl, columnar));
            let h = ctx
                .stream(Source::synthetic(n, |_, i| {
                    (i as i64, (i % 1000) as f64 * 0.5)
                }))
                .to_layer("edge")
                .to_layer("cloud")
                .key_by(move |t: &(i64, f64)| t.0 % k)
                .map_values(|t: (i64, f64)| t.1)
                .window::<f64>(size, WindowAgg::Sum)
                .collect();
            let mut report = ctx.execute().expect("mixed chain");
            let out: Vec<(i64, f64)> = report.take(h).expect("collect");
            let mut agg: std::collections::BTreeMap<i64, (usize, f64)> = Default::default();
            for (key, sum) in out {
                let slot = agg.entry(key).or_insert((0, 0.0));
                slot.0 += 1;
                slot.1 += sum;
            }
            agg.into_iter()
                .map(|(key, (windows, total))| (key, windows, total.to_bits()))
                .collect()
        };
        assert_eq!(run(true), run(false));
    });
}

/// Builds a column batch from `items` and checks every representation
/// law against the row path.
fn check_roundtrip<T: StreamData + Clone + PartialEq + std::fmt::Debug>(items: &[T]) {
    let layout = T::layout().expect("columnar type");
    let mut cols = layout.new_columns(items.len());
    for it in items {
        it.clone().append_columns(&mut cols);
    }
    let cb = ColumnBatch::new(layout.clone(), cols);
    assert_eq!(cb.len(), items.len());
    assert_eq!(cb.is_empty(), items.is_empty());
    for (i, it) in items.iter().enumerate() {
        assert_eq!(&T::read_columns(cb.columns(), i), it, "read_columns");
        let v = it.clone().into_value();
        assert_eq!(cb.row(i), v, "row materialization");
        assert_eq!(
            layout.hash_row(cb.columns(), i),
            v.stable_hash(),
            "hash_row must agree with stable_hash"
        );
    }
    // the columnar wire bytes are exactly the materialized row frame —
    // what lets column batches cross the socket unchanged
    assert_eq!(cb.wire().as_ref(), cb.to_batch().wire().as_ref());
}

#[test]
fn prop_streamdata_column_roundtrip() {
    forall("StreamData columns round-trip", 150, |g| {
        let n = g.usize_in(0, 40); // 0 ⇒ empty batches are covered
        check_roundtrip(&g.vec_of(n, |g| g.i64_in(i64::MIN / 2, i64::MAX / 2)));
        check_roundtrip(&g.vec_of(n, |g| g.f64_in(-1e12, 1e12)));
        check_roundtrip(&g.vec_of(n, |g| g.bool(0.5)));
        check_roundtrip(&g.vec_of(n, |g| g.ident(24)));
        check_roundtrip(&g.vec_of(n, |g| (g.i64_in(-1000, 1000), g.ident(8))));
        check_roundtrip(&g.vec_of(n, |g| (g.bool(0.3), (g.i64_in(0, 9), g.f64_in(-1.0, 1.0)))));
    });
}

#[test]
fn prop_computed_hash_column_matches_row_routing() {
    forall("hash column == per-row route_hash", 100, |g| {
        let n = g.usize_in(0, 40);
        let items: Vec<(i64, String)> =
            g.vec_of(n, |g| (g.i64_in(-100, 100), g.ident(12)));
        let layout = <(i64, String)>::layout().expect("pair layout");
        let mut cols = layout.new_columns(items.len());
        for it in &items {
            it.clone().append_columns(&mut cols);
        }
        // the key side of the Pair layout is the first leaf column
        let hashes: Vec<u64> = (0..items.len())
            .map(|i| Layout::I64.hash_row(&cols[..1], i))
            .collect();
        let cb = ColumnBatch::with_hashes(layout, cols, hashes.clone());
        let kept = cb.key_hashes().expect("well-formed hash column is kept");
        for (i, h) in kept.iter().enumerate() {
            assert_eq!(
                *h,
                route_hash(&cb.row(i)),
                "computed column must agree with the shuffle's row hash"
            );
        }
        // the column survives materialization to the Value fallback
        assert_eq!(cb.to_batch().key_hashes(), Some(hashes.as_slice()));
    });
}
