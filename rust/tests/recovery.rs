//! Integration tests of the checkpoint/recovery control plane: periodic
//! checkpoints through the epoch machinery, unplanned-failure recovery
//! (exactly-once output under injected instance death, including deaths
//! that land mid-checkpoint), and lag-driven elastic rescaling.

use flowunits::api::raw::{JobConfig, PlannerKind, Replication, Source, StreamContext};
use flowunits::config::eval_cluster;
use flowunits::coordinator::{AutoscaleConfig, Coordinator, JobReport};
use flowunits::value::Value;
use std::collections::BTreeMap;
use std::sync::atomic::{AtomicBool, AtomicI64, Ordering};
use std::sync::Arc;
use std::time::Duration;

fn recovery_config(checkpoint: Option<Duration>) -> JobConfig {
    JobConfig {
        planner: PlannerKind::FlowUnits,
        decouple_units: true,
        batch_size: 64,
        poll_timeout: Duration::from_millis(10),
        checkpoint_interval: checkpoint,
        ..Default::default()
    }
}

/// `source@edge → filter ∥ "agg"@cloud: map(fault/drag) → key_by % keys
/// → reduce(sum) → collect`. The map stage optionally panics on the
/// `bomb`-th event it processes (a one-shot global countdown — the
/// injected unplanned failure; replayed events keep decrementing past
/// zero and never re-fire) and drags each event while `heavy` is set
/// (the synthetic overload the autoscaler reacts to).
fn agg_graph(
    total: u64,
    rate: f64,
    keys: i64,
    config: &JobConfig,
    replication: Replication,
    bomb: Option<Arc<AtomicI64>>,
    heavy: Option<Arc<AtomicBool>>,
) -> flowunits::graph::LogicalGraph {
    let mut ctx = StreamContext::new(eval_cluster(None, Duration::ZERO), config.clone());
    ctx.stream(Source::synthetic_rated(total, rate, |_, i| {
        Value::I64(i as i64)
    }))
    .to_layer("edge")
    .filter(|v| v.as_i64().unwrap() >= 0)
    .unit("agg")
    .to_layer("cloud")
    .replicate(replication)
    .map(move |v| {
        if let Some(b) = &bomb {
            if b.fetch_sub(1, Ordering::SeqCst) == 1 {
                panic!("injected fault: test kills this instance");
            }
        }
        if let Some(h) = &heavy {
            if h.load(Ordering::Relaxed) {
                std::thread::sleep(Duration::from_micros(300));
            }
        }
        v
    })
    .key_by(move |v| Value::I64(v.as_i64().unwrap() % keys))
    .reduce(|a, b| Value::I64(a.as_i64().unwrap() + b.as_i64().unwrap()))
    .collect_vec();
    ctx.into_graph().unwrap()
}

fn run_agg(
    total: u64,
    rate: f64,
    keys: i64,
    config: JobConfig,
    bomb: Option<Arc<AtomicI64>>,
    heavy: Option<Arc<AtomicBool>>,
) -> JobReport {
    let coord = Coordinator::new(eval_cluster(None, Duration::ZERO), config.clone());
    let g = agg_graph(total, rate, keys, &config, Replication::PerCore, bomb, heavy);
    let dep = coord.deploy(&g).unwrap();
    dep.wait().unwrap()
}

fn sorted_sums(report: &JobReport) -> Vec<(i64, i64)> {
    let mut got: Vec<(i64, i64)> = report
        .collected
        .iter()
        .map(|v| {
            let (k, x) = v.as_pair().unwrap();
            (k.as_i64().unwrap(), x.as_i64().unwrap())
        })
        .collect();
    got.sort_unstable();
    got
}

/// Source instances enumerate disjoint global event indices, so the
/// correct per-key sums are a pure function of `total` and `keys`.
fn expected_sums(total: u64, keys: i64) -> Vec<(i64, i64)> {
    let mut sums: BTreeMap<i64, i64> = BTreeMap::new();
    for i in 0..total as i64 {
        *sums.entry(i % keys).or_insert(0) += i;
    }
    sums.into_iter().collect()
}

#[test]
fn instance_death_recovers_from_checkpoint_exactly_once() {
    let (total, keys) = (40_000u64, 16i64);
    let bomb = Arc::new(AtomicI64::new(12_000));
    let report = run_agg(
        total,
        4_000.0,
        keys,
        recovery_config(Some(Duration::from_millis(50))),
        Some(bomb.clone()),
        None,
    );
    assert!(bomb.load(Ordering::SeqCst) <= 0, "the injected fault fired");
    assert!(
        report.metrics.recoveries.load(Ordering::Relaxed) >= 1,
        "the supervisor recovered the dead unit-zone"
    );
    assert!(
        report.metrics.checkpoints_taken.load(Ordering::Relaxed) > 0,
        "periodic checkpoints were committed"
    );
    assert_eq!(
        sorted_sums(&report),
        expected_sums(total, keys),
        "per-key sums survive an instance death exactly — no loss, no duplication"
    );
}

#[test]
fn instance_death_without_any_committed_checkpoint_replays_from_scratch() {
    // kill almost immediately: recovery may find no committed checkpoint
    // yet and must fall back to a from-the-beginning replay (group
    // offsets were never advanced)
    let (total, keys) = (20_000u64, 8i64);
    let bomb = Arc::new(AtomicI64::new(500));
    let report = run_agg(
        total,
        4_000.0,
        keys,
        recovery_config(Some(Duration::from_millis(400))),
        Some(bomb.clone()),
        None,
    );
    assert!(bomb.load(Ordering::SeqCst) <= 0, "the injected fault fired");
    assert!(report.metrics.recoveries.load(Ordering::Relaxed) >= 1);
    assert_eq!(sorted_sums(&report), expected_sums(total, keys));
}

#[test]
fn prop_kill_at_random_points_under_load_is_exactly_once() {
    // property: wherever the fault lands — early, late, mid-checkpoint —
    // the recovered run produces exactly the no-fault per-key sums
    flowunits::proptest::forall("instance kill is exactly-once", 3, |g| {
        let keys = g.i64_in(1, 24);
        let kill_at = g.i64_in(2_000, 30_000);
        let interval_ms = [20u64, 50, 120][g.usize_in(0, 3)];
        let batch = [16usize, 64, 200][g.usize_in(0, 3)];
        let total = 36_000u64;
        let mut config = recovery_config(Some(Duration::from_millis(interval_ms)));
        config.batch_size = batch;
        let bomb = Arc::new(AtomicI64::new(kill_at));
        let report = run_agg(total, 4_500.0, keys, config, Some(bomb.clone()), None);
        assert!(bomb.load(Ordering::SeqCst) <= 0, "the injected fault fired");
        assert!(
            report.metrics.recoveries.load(Ordering::Relaxed) >= 1,
            "keys={keys} kill_at={kill_at} interval={interval_ms}ms: no recovery ran"
        );
        assert_eq!(
            sorted_sums(&report),
            expected_sums(total, keys),
            "keys={keys} kill_at={kill_at} interval={interval_ms}ms batch={batch}: \
             outputs diverged from the no-fault run"
        );
    });
}

#[test]
fn forced_checkpoint_is_invisible_in_output_and_observable_in_report() {
    // a checkpoint at a deterministic point must not disturb results,
    // and the report must carry the new observability surfaces
    let (total, keys) = (24_000u64, 8i64);
    let config = recovery_config(Some(Duration::from_secs(3600))); // manual ticks only
    let coord = Coordinator::new(eval_cluster(None, Duration::ZERO), config.clone());
    let g = agg_graph(
        total,
        2_000.0,
        keys,
        &config,
        Replication::PerCore,
        None,
        None,
    );
    let mut dep = coord.deploy(&g).unwrap();
    std::thread::sleep(Duration::from_millis(300));
    dep.checkpoint().unwrap();
    std::thread::sleep(Duration::from_millis(200));
    dep.checkpoint().unwrap();
    let report = dep.wait().unwrap();
    assert!(
        report.metrics.checkpoints_taken.load(Ordering::Relaxed) >= 2,
        "both forced checkpoints committed"
    );
    assert_eq!(report.events_in, total);
    assert_eq!(sorted_sums(&report), expected_sums(total, keys));
    // observability satellites: per-topic lag and per-instance batch
    // counts ride along in the report
    assert!(!report.queue_lag.is_empty(), "per-topic lag map present");
    assert!(report.queue_lag.keys().all(|k| k.starts_with("fu-s")));
    assert!(
        report.queue_lag.values().all(|&lag| lag == 0),
        "a finished job has drained all topics: {:?}",
        report.queue_lag
    );
    assert!(
        !report.instance_batches.is_empty(),
        "per-instance processed-batch counts present"
    );
    assert_eq!(
        report.metrics.state_append_failures.load(Ordering::Relaxed),
        0
    );
}

#[test]
fn state_topic_stays_bounded_across_many_checkpoint_cycles() {
    // every committed checkpoint supersedes the previous one's records in
    // the unit's state topic; compaction must tombstone the superseded
    // prefix so the topic's live payload stays bounded no matter how many
    // cycles run. Durable queues let the test reopen the log afterwards
    // and inspect what actually survived on disk.
    let dir = std::env::temp_dir().join(format!("fu-ckpt-compact-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    let (total, keys) = (12_000u64, 8i64);
    let mut config = recovery_config(Some(Duration::from_secs(3600))); // manual ticks only
    config.queue_dir = Some(dir.clone());
    let coord = Coordinator::new(eval_cluster(None, Duration::ZERO), config.clone());
    let g = agg_graph(
        total,
        6_000.0,
        keys,
        &config,
        Replication::PerCore,
        None,
        None,
    );
    let mut dep = coord.deploy(&g).unwrap();
    for _ in 0..8 {
        std::thread::sleep(Duration::from_millis(60));
        dep.checkpoint().unwrap();
    }
    let report = dep.wait().unwrap();
    assert!(
        report.metrics.checkpoints_taken.load(Ordering::Relaxed) >= 6,
        "repeated manual checkpoints committed"
    );
    assert!(
        report.metrics.state_compactions.load(Ordering::Relaxed) > 0,
        "superseded checkpoint records were compacted"
    );
    assert_eq!(
        sorted_sums(&report),
        expected_sums(total, keys),
        "compaction is invisible in the output"
    );
    drop(report);
    // reopen the durable log: all but the newest checkpoint's records must
    // be zero-length tombstones — the live payload does not grow with the
    // number of cycles
    let broker = flowunits::queue::QueueBroker::durable(&dir, None).unwrap();
    let topic = broker.topic("fu-state-u1", 1).unwrap();
    let part = topic.partition(0);
    let len = part.len();
    assert!(len > 0, "the agg unit checkpointed state into its topic");
    let (recs, _) = part.poll(0, len, Duration::ZERO).unwrap();
    let live = recs.iter().filter(|r| !r.is_empty()).count();
    assert!(
        live * 3 <= len,
        "most records should be tombstoned after 8 cycles (live={live} of {len})"
    );
    std::fs::remove_dir_all(&dir).unwrap();
}

#[test]
fn reopened_queue_dir_resumes_from_committed_checkpoints_without_new_input() {
    // Simulated coordinator restart, in-library. Phase A: a checkpointed
    // durable run leaves committed checkpoints (reduce state + covered
    // offsets) and the full event log in its queue dir. Phase B stands up
    // a *fresh* coordinator over the same dir with an identical graph
    // whose sources emit ZERO new events: it must adopt the newest
    // committed checkpoint per unit-zone, restore the reduce state,
    // re-commit the covered offsets, replay only the on-disk suffix past
    // them, and reproduce the exact full sums without any source rerun.
    let dir = std::env::temp_dir().join(format!("fu-coord-resume-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    let (total, keys) = (16_000u64, 8i64);
    let mut config = recovery_config(Some(Duration::from_millis(60)));
    config.queue_dir = Some(dir.clone());
    let report_a = run_agg(total, 6_000.0, keys, config, None, None);
    assert!(
        report_a.metrics.checkpoints_taken.load(Ordering::Relaxed) >= 1,
        "phase A committed at least one checkpoint"
    );
    assert_eq!(sorted_sums(&report_a), expected_sums(total, keys));
    drop(report_a);

    // hour-long interval: detection runs, but no new periodic checkpoint
    // muddies what phase B is being asked to prove
    let mut config_b = recovery_config(Some(Duration::from_secs(3600)));
    config_b.queue_dir = Some(dir.clone());
    let report_b = run_agg(0, 6_000.0, keys, config_b, None, None);
    assert!(
        report_b.metrics.recoveries.load(Ordering::Relaxed) >= 1,
        "the restarted coordinator adopted the committed checkpoints"
    );
    assert_eq!(report_b.events_in, 0, "no source re-read any input");
    assert_eq!(
        sorted_sums(&report_b),
        expected_sums(total, keys),
        "restored state + on-disk suffix replay reproduce the full sums"
    );
    std::fs::remove_dir_all(&dir).unwrap();
}

#[test]
fn autoscaler_scales_up_under_lag_then_back_down_without_losing_records() {
    // phase 1: one dragging instance falls behind a fast source — the
    // control loop must raise replication. phase 2: the drag is lifted,
    // lag drains, and replication steps back down. every record still
    // counts exactly once across all of the rescaling rolls.
    let (total, keys) = (40_000u64, 12i64);
    let mut config = recovery_config(None);
    config.autoscale = Some(AutoscaleConfig {
        sample_interval: Duration::from_millis(20),
        scale_up_lag: 1_500,
        scale_down_lag: 100,
        samples: 2,
        cooldown: Duration::from_millis(80),
        min_instances: 1,
        max_instances: 4,
    });
    let heavy = Arc::new(AtomicBool::new(true));
    let coord = Coordinator::new(eval_cluster(None, Duration::ZERO), config.clone());
    let g = agg_graph(
        total,
        2_500.0,
        keys,
        &config,
        Replication::Fixed(1),
        None,
        Some(heavy.clone()),
    );
    let dep = coord.deploy(&g).unwrap();
    // lift the synthetic overload partway through so lag can drain and
    // the scale-down leg of the hysteresis gets exercised
    std::thread::sleep(Duration::from_millis(700));
    heavy.store(false, Ordering::Relaxed);
    let report = dep.wait().unwrap();
    let ups = report.metrics.autoscale_ups.load(Ordering::Relaxed);
    let downs = report.metrics.autoscale_downs.load(Ordering::Relaxed);
    assert!(ups >= 1, "sustained lag raised replication (ups={ups})");
    assert!(
        downs >= 1,
        "drained lag lowered replication (ups={ups} downs={downs})"
    );
    assert_eq!(report.events_in, total);
    assert_eq!(
        sorted_sums(&report),
        expected_sums(total, keys),
        "per-key sums are exact across scale-up and scale-down rolls"
    );
}
