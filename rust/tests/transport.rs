//! Tier-2 integration tests for the distribution subsystem: the frame
//! codec under adversarial I/O, registration semantics, coordinator
//! restart / worker re-adoption, distributed-vs-in-process output parity,
//! and dead-worker detection — all over real Unix domain sockets.

use flowunits::transport::wire::{self, kind, FrameReader, ReadEvent};
use std::io::{self, Read, Write};

/// Deterministic xorshift64* — property tests without an RNG dependency.
struct Rng(u64);

impl Rng {
    fn next(&mut self) -> u64 {
        self.0 ^= self.0 << 13;
        self.0 ^= self.0 >> 7;
        self.0 ^= self.0 << 17;
        self.0.wrapping_mul(0x2545_F491_4F6C_DD1D)
    }
}

/// Accepts at most `cap` bytes per `write` call — exercises the
/// `write_all` retry path the way a full socket buffer would.
struct ShortWriter {
    buf: Vec<u8>,
    cap: usize,
}

impl Write for ShortWriter {
    fn write(&mut self, data: &[u8]) -> io::Result<usize> {
        let n = data.len().min(self.cap);
        self.buf.extend_from_slice(&data[..n]);
        Ok(n)
    }
    fn flush(&mut self) -> io::Result<()> {
        Ok(())
    }
}

/// Returns at most a few bytes per `read` call, with the chunk size
/// cycling — frames are torn at every possible boundary.
struct ChunkedReader<'a> {
    data: &'a [u8],
    pos: usize,
    step: usize,
}

impl Read for ChunkedReader<'_> {
    fn read(&mut self, out: &mut [u8]) -> io::Result<usize> {
        if self.pos >= self.data.len() {
            return Ok(0);
        }
        self.step = self.step % 7 + 1;
        let n = self.step.min(out.len()).min(self.data.len() - self.pos);
        out[..n].copy_from_slice(&self.data[self.pos..self.pos + n]);
        self.pos += n;
        Ok(n)
    }
}

#[test]
fn frame_roundtrip_survives_short_writes_and_partial_reads() {
    let mut rng = Rng(0x5eed_cafe);
    let kinds = [kind::DATA, kind::EOS, kind::EPOCH, kind::REPORT, kind::HEARTBEAT];
    let mut frames = Vec::new();
    let mut w = ShortWriter {
        buf: Vec::new(),
        cap: 3,
    };
    for _ in 0..200 {
        let k = kinds[(rng.next() % kinds.len() as u64) as usize];
        let len = (rng.next() % 4096) as usize;
        let payload: Vec<u8> = (0..len).map(|_| rng.next() as u8).collect();
        wire::write_frame(&mut w, k, &payload).unwrap();
        frames.push((k, payload));
    }
    let mut r = FrameReader::new(ChunkedReader {
        data: &w.buf,
        pos: 0,
        step: 0,
    });
    for (k, payload) in &frames {
        let f = r.next_frame().unwrap().expect("frame present");
        assert_eq!(f.kind, *k);
        assert_eq!(&f.payload, payload);
    }
    assert!(r.next_frame().unwrap().is_none(), "clean EOF after last frame");
}

/// Yields `WouldBlock` before every productive single-byte read — the
/// worst case of a socket with a read timeout.
struct StutterReader<'a> {
    data: &'a [u8],
    pos: usize,
    ready: bool,
}

impl Read for StutterReader<'_> {
    fn read(&mut self, out: &mut [u8]) -> io::Result<usize> {
        if !self.ready {
            self.ready = true;
            return Err(io::Error::new(io::ErrorKind::WouldBlock, "not ready"));
        }
        self.ready = false;
        if self.pos >= self.data.len() {
            return Ok(0);
        }
        out[0] = self.data[self.pos];
        self.pos += 1;
        Ok(1)
    }
}

#[test]
fn poll_preserves_partial_frames_across_timeouts() {
    let mut buf = Vec::new();
    wire::write_frame(&mut buf, kind::DATA, b"resumable").unwrap();
    let mut r = FrameReader::new(StutterReader {
        data: &buf,
        pos: 0,
        ready: false,
    });
    let mut idles = 0;
    let frame = loop {
        match r.poll().unwrap() {
            ReadEvent::Frame(f) => break f,
            ReadEvent::Idle => idles += 1,
            ReadEvent::Eof => panic!("eof before the frame completed"),
        }
    };
    assert_eq!(frame.payload, b"resumable");
    assert_eq!(idles as usize, buf.len(), "one Idle per byte delivered");
    assert!(matches!(r.poll().unwrap(), ReadEvent::Eof));
}

#[cfg(unix)]
mod multiprocess {
    use flowunits::api::raw::{JobConfig, StreamContext};
    use flowunits::config::eval_cluster;
    use flowunits::metrics::MetricsRegistry;
    use flowunits::pipelines;
    use flowunits::transport::daemon::{CoordinatorDaemon, JobManifest};
    use flowunits::transport::socket::Addr;
    use flowunits::transport::worker::{run_worker, WorkerOpts};
    use std::path::PathBuf;
    use std::sync::atomic::{AtomicBool, Ordering};
    use std::sync::Arc;
    use std::thread::JoinHandle;
    use std::time::{Duration, Instant};

    fn scratch(name: &str) -> PathBuf {
        let dir = std::env::temp_dir().join(format!("fu-it-{name}-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        dir
    }

    struct TestWorker {
        stop: Arc<AtomicBool>,
        thread: Option<JoinHandle<flowunits::error::Result<()>>>,
    }

    impl TestWorker {
        fn spawn(addr: &Addr, id: &str, dir: &std::path::Path) -> TestWorker {
            let stop = Arc::new(AtomicBool::new(false));
            let mut opts = WorkerOpts::new(addr.clone(), id);
            opts.state_dir = dir.join(id);
            opts.max_reconnects = 100;
            opts.stop = Some(stop.clone());
            let thread = std::thread::spawn(move || run_worker(opts));
            TestWorker {
                stop,
                thread: Some(thread),
            }
        }

        fn join(mut self) -> flowunits::error::Result<()> {
            self.stop.store(true, Ordering::SeqCst);
            self.thread.take().unwrap().join().expect("worker thread")
        }
    }

    fn wait_alive(daemon: &CoordinatorDaemon, n: usize) {
        let deadline = Instant::now() + Duration::from_secs(10);
        while daemon.workers().iter().filter(|(_, _, alive)| *alive).count() < n {
            assert!(Instant::now() < deadline, "workers never registered");
            std::thread::sleep(Duration::from_millis(20));
        }
    }

    fn in_process_collected(pipeline: &str, events: u64) -> Vec<String> {
        let mut ctx = StreamContext::new(eval_cluster(None, Duration::ZERO), JobConfig::default());
        pipelines::build(&mut ctx, pipeline, events).unwrap();
        let report = ctx.execute().unwrap();
        pipelines::render_collected(&report.collected)
    }

    #[test]
    fn duplicate_worker_id_is_rejected() {
        let dir = scratch("dup");
        let addr = Addr::parse(&dir.join("c.sock").to_string_lossy());
        let mut daemon = CoordinatorDaemon::start(
            addr.clone(),
            Duration::from_millis(200),
            MetricsRegistry::new(),
        )
        .unwrap();
        let first = TestWorker::spawn(&addr, "dup", &dir);
        wait_alive(&daemon, 1);

        let mut opts = WorkerOpts::new(addr.clone(), "dup");
        opts.state_dir = dir.join("second");
        opts.reconnect = false;
        let err = run_worker(opts).unwrap_err();
        assert!(
            err.to_string().contains("registration rejected"),
            "second registration of a live id must be rejected, got: {err}"
        );

        first.join().unwrap();
        daemon.shutdown();
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn worker_survives_coordinator_restart_and_is_readopted() {
        let dir = scratch("readopt");
        let addr = Addr::parse(&dir.join("c.sock").to_string_lossy());
        let mut first = CoordinatorDaemon::start(
            addr.clone(),
            Duration::from_millis(200),
            MetricsRegistry::new(),
        )
        .unwrap();
        let worker = TestWorker::spawn(&addr, "phoenix", &dir);
        wait_alive(&first, 1);
        first.shutdown();

        // same address, brand-new daemon: the worker's reconnect loop must
        // re-register, and the restarted coordinator must be able to run a
        // job through it
        let mut second = CoordinatorDaemon::start(
            addr.clone(),
            Duration::from_millis(200),
            MetricsRegistry::new(),
        )
        .unwrap();
        wait_alive(&second, 1);
        let report = second.run_job("wordcount", 600, 1, Duration::from_secs(30)).unwrap();
        assert_eq!(report.workers, vec!["phoenix".to_string()]);
        assert_eq!(
            pipelines::render_collected(&report.collected),
            in_process_collected("wordcount", 600),
            "post-restart distributed run must match the in-process run"
        );

        second.shutdown_workers();
        worker.join().unwrap();
        second.shutdown();
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn distributed_wordcount_matches_in_process_output() {
        let dir = scratch("parity");
        let addr = Addr::parse(&dir.join("c.sock").to_string_lossy());
        let mut daemon = CoordinatorDaemon::start(
            addr.clone(),
            Duration::from_millis(500),
            MetricsRegistry::new(),
        )
        .unwrap();
        let alpha = TestWorker::spawn(&addr, "alpha", &dir);
        let beta = TestWorker::spawn(&addr, "beta", &dir);

        let report = daemon.run_job("wordcount", 600, 2, Duration::from_secs(30)).unwrap();
        assert_eq!(
            report.workers,
            vec!["alpha".to_string(), "beta".to_string()],
            "both workers participate"
        );
        assert_eq!(report.events_in, 600);
        assert_eq!(
            pipelines::render_collected(&report.collected),
            in_process_collected("wordcount", 600),
            "distributed output must be identical to the in-process run"
        );

        daemon.shutdown_workers();
        alpha.join().unwrap();
        beta.join().unwrap();
        daemon.shutdown();
        let _ = std::fs::remove_dir_all(&dir);
    }

    /// A worker SIGKILLed mid-run no longer fails the job: the daemon
    /// detects the death (socket EOF), aborts the attempt with an error
    /// naming the worker, and redispatches the job over the survivor.
    /// Pipelines are deterministic, so the rerun's output must still be
    /// byte-identical to the in-process engine's.
    #[test]
    fn killing_a_worker_mid_run_redispatches_over_the_survivor() {
        let dir = scratch("kill");
        let addr = Addr::parse(&dir.join("c.sock").to_string_lossy());
        let addr_str = addr.to_string();
        let metrics = MetricsRegistry::new();
        let daemon = Arc::new(
            CoordinatorDaemon::start(addr.clone(), Duration::from_millis(200), metrics.clone())
                .unwrap(),
        );
        let survivor = TestWorker::spawn(&addr, "survivor", &dir);
        // the victim is a real OS process so we can SIGKILL it mid-run
        let mut victim = std::process::Command::new(env!("CARGO_BIN_EXE_flowunits"))
            .arg("worker")
            .arg("--connect")
            .arg(&addr_str)
            .arg("--id")
            .arg("victim")
            .arg("--state-dir")
            .arg(dir.join("victim"))
            .stdout(std::process::Stdio::null())
            .stderr(std::process::Stdio::null())
            .spawn()
            .expect("spawn victim worker process");
        wait_alive(&daemon, 2);

        // paced source: the job takes seconds, the kill lands mid-run
        let events = 300_000;
        let runner = {
            let daemon = daemon.clone();
            std::thread::spawn(move || {
                daemon.run_job("wordcount_paced", events, 2, Duration::from_secs(120))
            })
        };
        std::thread::sleep(Duration::from_millis(700));
        victim.kill().expect("kill victim");
        let _ = victim.wait();

        let report = runner
            .join()
            .expect("runner thread")
            .expect("job must be redispatched over the survivor, not failed");
        assert_eq!(
            report.workers,
            vec!["survivor".to_string()],
            "successful attempt runs on the lone survivor"
        );
        assert_eq!(report.events_in, events);
        assert_eq!(
            pipelines::render_collected(&report.collected),
            in_process_collected("wordcount_paced", events),
            "post-redispatch output must match the in-process run"
        );
        assert!(
            metrics.recoveries.load(Ordering::Relaxed) >= 1,
            "the redispatch is counted as a recovery"
        );

        daemon.shutdown_workers();
        survivor.join().unwrap();
        drop(daemon); // Drop shuts the daemon down
        let _ = std::fs::remove_dir_all(&dir);
    }

    /// Dispatching with a data dir persists a [`JobManifest`] for the
    /// whole life of the job and removes it at completion — the file is
    /// exactly the "was a job in flight?" marker a restarted coordinator
    /// checks.
    #[test]
    fn dispatch_persists_a_manifest_until_the_job_completes() {
        let dir = scratch("manifest-live");
        let data = dir.join("data");
        let addr = Addr::parse(&dir.join("c.sock").to_string_lossy());
        let mut daemon = CoordinatorDaemon::start(
            addr.clone(),
            Duration::from_millis(200),
            MetricsRegistry::new(),
        )
        .unwrap();
        daemon.set_data_dir(&data);
        let daemon = Arc::new(daemon);
        let worker = TestWorker::spawn(&addr, "solo", &dir);
        wait_alive(&daemon, 1);

        let events = 150_000; // paced: in flight for several seconds
        let runner = {
            let daemon = daemon.clone();
            std::thread::spawn(move || {
                daemon.run_job("wordcount_paced", events, 1, Duration::from_secs(120))
            })
        };
        let deadline = Instant::now() + Duration::from_secs(20);
        let manifest = loop {
            if let Some(m) = JobManifest::load(&data) {
                break m;
            }
            assert!(
                Instant::now() < deadline,
                "dispatch never persisted a job manifest"
            );
            std::thread::sleep(Duration::from_millis(20));
        };
        assert_eq!(manifest.pipeline, "wordcount_paced");
        assert_eq!(manifest.events, events);
        assert_eq!(manifest.workers, 1);
        assert!(
            !manifest.assign.is_empty(),
            "manifest records the host assignment"
        );

        runner.join().expect("runner thread").unwrap();
        assert!(
            JobManifest::load(&data).is_none(),
            "completion removes the manifest"
        );

        daemon.shutdown_workers();
        worker.join().unwrap();
        drop(daemon);
        let _ = std::fs::remove_dir_all(&dir);
    }

    /// A coordinator that dies mid-job leaves its manifest on disk. Its
    /// successor finds the pending job, the worker re-registers through
    /// its reconnect loop, and re-running the manifested job produces the
    /// same output the original would have.
    #[test]
    fn restarted_coordinator_resumes_the_job_a_dead_predecessor_left_behind() {
        let dir = scratch("manifest-resume");
        let data = dir.join("data");
        let addr = Addr::parse(&dir.join("c.sock").to_string_lossy());
        // the dead predecessor's leavings: exactly what a SIGKILL after
        // dispatch leaves behind
        JobManifest {
            pipeline: "wordcount".into(),
            events: 600,
            checkpoint_ms: 0,
            workers: 1,
            assign: vec![("host".into(), "redo".into())],
        }
        .save(&data)
        .unwrap();

        let mut daemon = CoordinatorDaemon::start(
            addr.clone(),
            Duration::from_millis(200),
            MetricsRegistry::new(),
        )
        .unwrap();
        daemon.set_data_dir(&data);
        let worker = TestWorker::spawn(&addr, "redo", &dir);

        let pending = daemon.pending_job().expect("manifest found on startup");
        assert_eq!(pending.pipeline, "wordcount");
        let report = daemon
            .run_job(
                &pending.pipeline,
                pending.events,
                pending.workers,
                Duration::from_secs(30),
            )
            .unwrap();
        assert_eq!(
            pipelines::render_collected(&report.collected),
            in_process_collected("wordcount", 600),
            "resumed run must match the in-process run"
        );
        assert!(
            daemon.pending_job().is_none(),
            "resume completion clears the manifest"
        );

        daemon.shutdown_workers();
        worker.join().unwrap();
        daemon.shutdown();
        let _ = std::fs::remove_dir_all(&dir);
    }
}
