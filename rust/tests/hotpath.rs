//! Hot-path overhaul tests (PR 5): pre-partitioned hash-shuffle parity
//! with the per-record reference path, event-driven queue wait-set
//! consumption, zero per-operator allocation on steady-state chains
//! (asserted through the buffer-reuse metric), and the poll-cap knob.

use flowunits::api::raw::{JobConfig, PlannerKind, Source, StreamContext};
use flowunits::channels::{route_hash, Inbox, Msg, OutPort, Routing, Target};
use flowunits::config::eval_cluster;
use flowunits::metrics::MetricsRegistry;
use flowunits::proptest::forall;
use flowunits::queue::QueueBroker;
use flowunits::runtime::exec::{ChainBuffers, FilterExec, KeyByExec, MapExec, OpExec};
use flowunits::runtime::run_chain;
use flowunits::value::{Batch, Value};
use std::sync::atomic::Ordering;
use std::sync::mpsc::{sync_channel, Receiver};
use std::sync::Arc;
use std::time::{Duration, Instant};

fn local_targets(n: usize, cap: usize) -> (Vec<Target>, Vec<Receiver<Msg>>) {
    let mut targets = Vec::new();
    let mut rxs = Vec::new();
    for _ in 0..n {
        let (tx, rx) = sync_channel(cap);
        targets.push(Target::local(tx));
        rxs.push(rx);
    }
    (targets, rxs)
}

/// The pre-partitioned batch shuffle must deliver, per target, exactly
/// the record sequence the old per-record path (`route_hash` + push, in
/// arrival order) produced — same multiset per target *and* per-key
/// order preserved — whether or not batches carry the key-hash column,
/// and regardless of how records are grouped into batches.
#[test]
fn prop_prepartitioned_shuffle_matches_per_record_reference() {
    forall("shuffle parity", 48, |g| {
        let n_targets = g.usize_in(1, 5);
        let n_records = g.usize_in(0, 161);
        let batch_capacity = g.usize_in(1, 48);
        let values: Vec<Value> = (0..n_records)
            .map(|i| {
                Value::pair(
                    Value::Str(format!("k{}", g.usize_in(0, 13))),
                    Value::I64(i as i64),
                )
            })
            .collect();
        // reference: the old per-record path
        let mut expected: Vec<Vec<Value>> = vec![Vec::new(); n_targets];
        for v in &values {
            let t = (route_hash(v) % n_targets as u64) as usize;
            expected[t].push(v.clone());
        }
        // new path: random batch boundaries, column attached at random
        let (targets, rxs) = local_targets(n_targets, 4096);
        let mut port = OutPort::new(targets, Routing::Hash, batch_capacity, None);
        let mut rest = values.as_slice();
        while !rest.is_empty() {
            let take = g.usize_in(1, rest.len() + 1).min(rest.len());
            let (chunk, tail) = rest.split_at(take);
            rest = tail;
            let chunk = chunk.to_vec();
            let batch = if g.bool(0.5) {
                let hashes: Vec<u64> = chunk.iter().map(route_hash).collect();
                Batch::with_hashes(chunk, hashes)
            } else {
                chunk.into() // column-less: on-the-fly fallback
            };
            port.send(batch);
        }
        port.eos();
        for (t, rx) in rxs.into_iter().enumerate() {
            let mut inbox = Inbox::new(rx, 1);
            let mut got = Vec::new();
            while let Some(b) = inbox.recv() {
                got.extend(b.into_values());
            }
            assert_eq!(
                got, expected[t],
                "target {t} of {n_targets} (cap {batch_capacity})"
            );
        }
    });
}

/// A consumer owning N partitions parks once on the topic wait-set and
/// is woken by a single append to *any* of them — no 1 ms-floor
/// timed-poll staircase across partitions.
#[test]
fn wait_set_wakes_many_partition_consumer_on_any_append() {
    let m = MetricsRegistry::new();
    let broker = QueueBroker::in_memory(Some(m.clone()));
    let topic = broker.topic("ws", 32).unwrap();
    topic.register_producer();
    let parts: Vec<usize> = (0..32).collect();
    let mut offsets = vec![0usize; 32];
    for target in [3u64, 17, 30] {
        let t2 = topic.clone();
        let h = std::thread::spawn(move || {
            std::thread::sleep(Duration::from_millis(120));
            t2.append(target, &target.to_le_bytes()).unwrap();
        });
        let t0 = Instant::now();
        let drained = loop {
            let d = topic
                .poll_many(&parts, &mut offsets, 64, Duration::from_secs(30))
                .unwrap();
            if !d.is_empty() {
                break d;
            }
        };
        h.join().unwrap();
        assert!(
            t0.elapsed() < Duration::from_secs(10),
            "append to partition {target} woke the consumer"
        );
        assert_eq!(drained.len(), 1);
        assert_eq!(drained[0].0 as u64, target);
    }
    assert!(
        m.queue_wakeups.load(Ordering::Relaxed) >= 1,
        "consumption was wakeup-driven"
    );
    // closing the topic also wakes the parked consumer into EOS
    let t2 = topic.clone();
    let h = std::thread::spawn(move || {
        std::thread::sleep(Duration::from_millis(80));
        t2.producer_done();
    });
    let t0 = Instant::now();
    loop {
        match topic.poll_many(&parts, &mut offsets, 64, Duration::from_secs(30)) {
            None => break,
            Some(d) => assert!(d.is_empty(), "no data was appended"),
        }
    }
    h.join().unwrap();
    assert!(t0.elapsed() < Duration::from_secs(10), "close woke the consumer");
}

/// Steady-state chains allocate nothing per operator: after warmup,
/// every interior buffer hand-off reuses a recycled allocation and the
/// only allocation per batch is the single chain-edge `Batch` (at most
/// one `chain_buffer_allocs` tick per invocation).
#[test]
fn steady_state_chain_reuses_buffers_with_zero_per_operator_allocs() {
    let m = MetricsRegistry::new();
    let mut bufs = ChainBuffers::new(Some(m.clone()));
    let mut ops: Vec<Box<dyn OpExec>> = vec![
        Box::new(MapExec(Arc::new(|v: Value| {
            Value::I64(v.as_i64().unwrap() + 1)
        }))),
        Box::new(FilterExec(Arc::new(|v: &Value| {
            v.as_i64().unwrap() % 2 == 0
        }))),
        Box::new(KeyByExec(Arc::new(|v: &Value| {
            Value::I64(v.as_i64().unwrap() % 4)
        }))),
    ];
    let batch_of = |n: usize| -> Batch {
        (0..n as i64).map(Value::I64).collect::<Vec<_>>().into()
    };
    // warmup: buffers grow to steady-state capacity
    for _ in 0..5 {
        run_chain(&mut ops, batch_of(64), &mut bufs);
    }
    let allocs0 = m.chain_buffer_allocs.load(Ordering::Relaxed);
    let reuses0 = m.chain_buffer_reuses.load(Ordering::Relaxed);
    let rounds = 40u64;
    for _ in 0..rounds {
        let out = run_chain(&mut ops, batch_of(64), &mut bufs);
        assert_eq!(out.len(), 32);
        assert!(out.key_hashes().is_some(), "keying chain attaches the column");
    }
    let allocs = m.chain_buffer_allocs.load(Ordering::Relaxed) - allocs0;
    let reuses = m.chain_buffer_reuses.load(Ordering::Relaxed) - reuses0;
    assert!(
        allocs <= rounds,
        "at most one allocation per batch (the chain-edge Batch payload), \
         zero per operator — got {allocs} allocs over {rounds} batches"
    );
    assert_eq!(
        reuses,
        rounds * 2,
        "every interior hand-off (2 per batch for a 3-op chain) reused a \
         recycled buffer"
    );
}

/// End-to-end: a decoupled keyed pipeline with a tiny poll cap still
/// delivers every record exactly once, and the cap bounds per-wakeup
/// drains (the knob replaces the hardcoded 64-record cap).
#[test]
fn poll_cap_knob_bounds_drains_without_losing_records() {
    let config = JobConfig {
        planner: PlannerKind::FlowUnits,
        decouple_units: true,
        batch_size: 16,
        poll_timeout: Duration::from_millis(10),
        poll_max_records: 3,
        ..Default::default()
    };
    let mut ctx = StreamContext::new(eval_cluster(None, Duration::ZERO), config);
    ctx.stream(Source::synthetic(2000, |_, i| Value::I64(i as i64)))
        .to_layer("edge")
        .filter(|v| v.as_i64().unwrap() % 2 == 0)
        .to_layer("cloud")
        .collect_count();
    let report = ctx.execute().expect("pipeline with poll_max_records = 3");
    assert_eq!(report.events_out, 1000);
}

/// End-to-end keyed shuffle across decoupled FlowUnit boundaries: the
/// hash-column fast path and the wire-decode fallback must agree with
/// the direct-channel deployment record for record.
#[test]
fn keyed_wordcount_agrees_between_decoupled_and_direct_deployments() {
    let run = |decouple: bool| -> Vec<(String, i64)> {
        let config = JobConfig {
            planner: PlannerKind::FlowUnits,
            decouple_units: decouple,
            batch_size: 32,
            poll_timeout: Duration::from_millis(10),
            ..Default::default()
        };
        let mut ctx = StreamContext::new(eval_cluster(None, Duration::ZERO), config);
        ctx.stream(Source::synthetic(3000, |_, i| {
            Value::Str(format!("w{}", i % 23))
        }))
        .to_layer("edge")
        .to_layer("cloud")
        .key_by(|v| v.clone())
        .fold(Value::I64(0), |acc: &mut Value, _v: Value| {
            *acc = Value::I64(acc.as_i64().unwrap() + 1);
        })
        .collect_vec();
        let report = ctx.execute().expect("keyed wordcount");
        let mut counts: Vec<(String, i64)> = report
            .collected
            .iter()
            .map(|v| {
                let (k, c) = v.as_pair().unwrap();
                (k.as_str().unwrap().to_string(), c.as_i64().unwrap())
            })
            .collect();
        counts.sort();
        counts
    };
    let direct = run(false);
    let decoupled = run(true);
    assert_eq!(direct, decoupled);
    assert_eq!(direct.len(), 23);
    assert!(direct.iter().all(|(_, c)| *c * 23 >= 3000 - 23));
}
