//! Disorder coverage for the event-time subsystem: the same keyed
//! event-time pipeline must produce identical window outputs whether the
//! events arrive ordered or latency-shuffled, as long as the disorder
//! stays within the watermark bound (scenario A, a property test over
//! deterministic netsim-shaped delivery schedules); and records arriving
//! beyond the allowed lateness must be *counted and captured*, never
//! silently lost (scenario B, a conservation check).

use flowunits::config::eval_cluster;
use flowunits::prelude::*;
use flowunits::proptest::forall;
use std::sync::atomic::{AtomicI64, Ordering};
use std::sync::Arc;
use std::time::Duration;

/// Event-time over the queue substrate: unit boundaries decoupled, so
/// watermarks travel as sentinel records through the topic logs.
fn queued_config(idle: Option<Duration>, checkpoint: Option<Duration>) -> JobConfig {
    JobConfig {
        decouple_units: true,
        batch_size: 16,
        poll_timeout: Duration::from_millis(10),
        idle_timeout: idle,
        checkpoint_interval: checkpoint,
        ..Default::default()
    }
}

/// Runs `(key, ts)` events (delivered in vector order) through
/// `assign_timestamps(bounded(bound_ms))` → `key_by` → tumbling 100 ms
/// `event_window(Count, lateness_ms)` with a late side output. Returns
/// the sorted `(key, count)` window outputs, the sorted late-side
/// records, and the `late_records` metric.
///
/// `single_instance` pins both units to one cloud instance so delivery
/// order is exactly vector order (scenario B needs the straggler to
/// arrive strictly after the high watermark); otherwise the source runs
/// at the edge, striped across zones, and results flow over shaped links
/// to the cloud — watermarks min-merge across the fan-in.
fn run_windows(
    events: Vec<(i64, i64)>,
    bound_ms: i64,
    lateness_ms: i64,
    latency: Duration,
    single_instance: bool,
) -> (Vec<(i64, i64)>, Vec<(i64, (i64, i64))>, u64) {
    let mut ctx = StreamContext::new(eval_cluster(None, latency), JobConfig::default());
    let mut s = ctx.stream(Source::vector(events)).unit("ingest");
    s = if single_instance {
        s.to_layer("cloud").replicate(Replication::Fixed(1))
    } else {
        s.to_layer("edge")
    };
    let mut s = s
        .assign_timestamps(|e: &(i64, i64)| e.1, WatermarkGen::bounded(bound_ms))
        .unit("agg");
    s = if single_instance {
        s.to_layer("cloud").replicate(Replication::Fixed(1))
    } else {
        s.to_layer("cloud")
    };
    let (wins, late) = s.key_by(|e: &(i64, i64)| e.0).event_window_with_late::<i64>(
        |e| e.1,
        WindowAssigner::tumbling(100),
        WindowAgg::Count,
        lateness_ms,
    );
    let wins = wins.collect();
    let mut report = ctx.execute().unwrap();
    let mut got: Vec<(i64, i64)> = report.take(wins).unwrap();
    got.sort_unstable();
    let mut lates: Vec<(i64, (i64, i64))> = report.take(late).unwrap();
    lates.sort_unstable();
    let late_metric = report.metrics.late_records.load(Ordering::Relaxed);
    (got, lates, late_metric)
}

#[test]
fn prop_bounded_disorder_is_invisible_to_event_windows() {
    forall("ordered vs latency-shuffled window parity", 5, |g| {
        let n = 4_000i64;
        let step = 5i64;
        let keys = g.i64_in(2, 6);
        // delivery schedule: each event is delayed by a random latency in
        // [0, max_delay) ms, then the stream is replayed in arrival order
        // — the deterministic shape of a jittery network link
        let max_delay = g.i64_in(3, 8) * step;
        let ordered: Vec<(i64, i64)> = (0..n).map(|i| (i % keys, i * step)).collect();
        let mut arrival: Vec<(i64, (i64, i64))> = ordered
            .iter()
            .map(|&(k, ts)| (ts + g.i64_in(0, max_delay), (k, ts)))
            .collect();
        arrival.sort_by_key(|&(at, (_, ts))| (at, ts));
        let shuffled: Vec<(i64, i64)> = arrival.into_iter().map(|(_, e)| e).collect();
        assert_ne!(ordered, shuffled, "the schedule actually reordered something");

        let (base, base_late, base_metric) =
            run_windows(ordered, max_delay, 0, Duration::ZERO, false);
        let (got, got_late, got_metric) =
            run_windows(shuffled, max_delay, 0, Duration::from_millis(1), false);
        assert_eq!(
            base, got,
            "keys={keys} max_delay={max_delay}ms: disorder within the watermark \
             bound changed the window outputs"
        );
        let total: i64 = base.iter().map(|&(_, c)| c).sum();
        assert_eq!(total, n, "every record landed in exactly one pane");
        assert_eq!((base_metric, got_metric), (0, 0), "no record counted late");
        assert!(base_late.is_empty() && got_late.is_empty());
    });
}

#[test]
fn late_beyond_lateness_is_counted_and_captured_not_lost() {
    let keys = 4i64;
    let on_time = 3_000i64;
    let mut events: Vec<(i64, i64)> = (0..on_time).map(|i| (i % keys, i * 5)).collect();
    // stragglers: event times from the distant past, delivered last — far
    // beyond bound (40 ms) + lateness (100 ms) behind the watermark
    let stragglers = vec![(0i64, 0i64), (1, 120), (2, 250)];
    events.extend(stragglers.iter().copied());
    let total = events.len() as i64;

    let (wins, lates, late_metric) =
        run_windows(events, 40, 100, Duration::ZERO, true);
    assert_eq!(late_metric, stragglers.len() as u64, "each straggler counted once");
    let expected_lates: Vec<(i64, (i64, i64))> =
        stragglers.iter().map(|&(k, ts)| (k, (k, ts))).collect();
    assert_eq!(lates, expected_lates, "the side output captures the late records");
    // conservation: pane contents + late records account for every event
    let paned: i64 = wins.iter().map(|&(_, c)| c).sum();
    assert_eq!(
        paned + late_metric as i64,
        total,
        "no record was silently dropped"
    );
    assert_eq!(paned, on_time, "on-time records all fired in panes");
}

#[test]
fn idle_timeout_waives_a_silent_source_instance_for_event_time() {
    // Two source instances feed one queued event-time merge. Instance 0
    // paces 1000 fresh-timestamped events over ~500 ms; instance 1 stays
    // silent for 800 ms, then bursts 1000 records stamped deep in
    // instance 0's past. With an idleness timeout, the min-of-inputs
    // merge waives the silent instance: event time advances on instance
    // 0's promises alone, the early panes fire, and instance 1's
    // eventual records are counted *and captured* late — never silently
    // dropped. Without the timeout the strict merge holds event time
    // down until instance 1 speaks, so the very same schedule is fully
    // on time.
    let half = 1_000u64;
    let run = |idle: Option<Duration>| -> (i64, u64, u64) {
        let mut ctx = StreamContext::new(
            eval_cluster(None, Duration::ZERO),
            queued_config(idle, None),
        );
        let (wins, late) = ctx
            .stream(Source::synthetic_rated(half * 2, 2_000.0, move |inst, i| {
                if inst == 0 {
                    ((i % 4) as i64, i as i64 * 5)
                } else {
                    if i == half {
                        std::thread::sleep(Duration::from_millis(800));
                    }
                    ((i % 4) as i64, (i % 50) as i64)
                }
            }))
            .unit("ingest")
            .to_layer("cloud")
            .replicate(Replication::Fixed(2))
            .assign_timestamps(|e: &(i64, i64)| e.1, WatermarkGen::bounded(20))
            .unit("agg")
            .to_layer("cloud")
            .replicate(Replication::Fixed(1))
            .key_by(|e: &(i64, i64)| e.0)
            .event_window_with_late::<i64>(
                |e| e.1,
                WindowAssigner::tumbling(100),
                WindowAgg::Count,
                0,
            );
        let wins = wins.collect();
        let mut report = ctx.execute().unwrap();
        let got: Vec<(i64, i64)> = report.take(wins).unwrap();
        let lates: Vec<(i64, (i64, i64))> = report.take(late).unwrap();
        let metric = report.metrics.late_records.load(Ordering::Relaxed);
        let paned: i64 = got.iter().map(|&(_, c)| c).sum();
        (paned, lates.len() as u64, metric)
    };

    let (paned, captured, metric) = run(Some(Duration::from_millis(200)));
    assert!(
        metric > 0,
        "the waived merge advanced event time past the silent instance"
    );
    assert_eq!(captured, metric, "every late record is captured, not dropped");
    assert_eq!(
        paned + metric as i64,
        (half * 2) as i64,
        "conservation: paned + late accounts for every record"
    );

    let (paned, captured, metric) = run(None);
    assert_eq!(
        (captured, metric),
        (0, 0),
        "strict semantics: the merge waited for the silent instance"
    );
    assert_eq!(paned, (half * 2) as i64);
}

#[test]
fn recovery_replay_does_not_regress_watermarks_or_refire_panes() {
    // Checkpointed queued event-time job with a mid-run instance kill:
    // recovery restores the window state (including its clock) and
    // replays the entry-log suffix — the stale watermark sentinels
    // interleaved in that replay must not wind the merged clock
    // backwards, and restored panes must not re-fire. Pane counts must
    // equal the no-fault run exactly.
    let n = 20_000u64;
    let keys = 4i64;
    let run = |bomb: Option<Arc<AtomicI64>>| -> (Vec<(i64, i64)>, u64, JobReport) {
        let mut ctx = StreamContext::new(
            eval_cluster(None, Duration::ZERO),
            queued_config(None, Some(Duration::from_millis(50))),
        );
        let b = bomb.clone();
        let (wins, late) = ctx
            .stream(Source::synthetic_rated(n, 30_000.0, move |_, i| {
                (i as i64 % keys, i as i64 * 5)
            }))
            .unit("ingest")
            .to_layer("edge")
            .assign_timestamps(|e: &(i64, i64)| e.1, WatermarkGen::bounded(25))
            .unit("agg")
            .to_layer("cloud")
            .replicate(Replication::Fixed(1))
            .map(move |e: (i64, i64)| {
                if let Some(b) = &b {
                    if b.fetch_sub(1, Ordering::SeqCst) == 1 {
                        panic!("injected fault: test kills this instance");
                    }
                }
                e
            })
            .key_by(|e: &(i64, i64)| e.0)
            .event_window_with_late::<i64>(
                |e| e.1,
                WindowAssigner::tumbling(100),
                WindowAgg::Count,
                0,
            );
        let wins = wins.collect();
        let mut report = ctx.execute().unwrap();
        let mut got: Vec<(i64, i64)> = report.take(wins).unwrap();
        got.sort_unstable();
        let lates: Vec<(i64, (i64, i64))> = report.take(late).unwrap();
        (got, lates.len() as u64, report)
    };

    let (base, base_late, base_report) = run(None);
    let total: i64 = base.iter().map(|&(_, c)| c).sum();
    assert_eq!(total, n as i64, "reference run paned every record");
    assert_eq!(base_late, 0, "an ordered source is never late");
    assert_eq!(base_report.metrics.late_records.load(Ordering::Relaxed), 0);

    let bomb = Arc::new(AtomicI64::new(7_000));
    let (got, got_late, report) = run(Some(bomb.clone()));
    assert!(bomb.load(Ordering::SeqCst) <= 0, "the injected fault fired");
    assert!(
        report.metrics.recoveries.load(Ordering::Relaxed) >= 1,
        "the supervisor recovered the dead unit-zone"
    );
    assert_eq!(got_late, 0, "replayed sentinels made nothing spuriously late");
    assert_eq!(
        got, base,
        "pane counts survive recovery replay exactly — no regressed clock, no re-fired pane"
    );
}
