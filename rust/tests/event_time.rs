//! Disorder coverage for the event-time subsystem: the same keyed
//! event-time pipeline must produce identical window outputs whether the
//! events arrive ordered or latency-shuffled, as long as the disorder
//! stays within the watermark bound (scenario A, a property test over
//! deterministic netsim-shaped delivery schedules); and records arriving
//! beyond the allowed lateness must be *counted and captured*, never
//! silently lost (scenario B, a conservation check).

use flowunits::config::eval_cluster;
use flowunits::prelude::*;
use flowunits::proptest::forall;
use std::sync::atomic::Ordering;
use std::time::Duration;

/// Runs `(key, ts)` events (delivered in vector order) through
/// `assign_timestamps(bounded(bound_ms))` → `key_by` → tumbling 100 ms
/// `event_window(Count, lateness_ms)` with a late side output. Returns
/// the sorted `(key, count)` window outputs, the sorted late-side
/// records, and the `late_records` metric.
///
/// `single_instance` pins both units to one cloud instance so delivery
/// order is exactly vector order (scenario B needs the straggler to
/// arrive strictly after the high watermark); otherwise the source runs
/// at the edge, striped across zones, and results flow over shaped links
/// to the cloud — watermarks min-merge across the fan-in.
fn run_windows(
    events: Vec<(i64, i64)>,
    bound_ms: i64,
    lateness_ms: i64,
    latency: Duration,
    single_instance: bool,
) -> (Vec<(i64, i64)>, Vec<(i64, (i64, i64))>, u64) {
    let mut ctx = StreamContext::new(eval_cluster(None, latency), JobConfig::default());
    let mut s = ctx.stream(Source::vector(events)).unit("ingest");
    s = if single_instance {
        s.to_layer("cloud").replicate(Replication::Fixed(1))
    } else {
        s.to_layer("edge")
    };
    let mut s = s
        .assign_timestamps(|e: &(i64, i64)| e.1, WatermarkGen::bounded(bound_ms))
        .unit("agg");
    s = if single_instance {
        s.to_layer("cloud").replicate(Replication::Fixed(1))
    } else {
        s.to_layer("cloud")
    };
    let (wins, late) = s.key_by(|e: &(i64, i64)| e.0).event_window_with_late::<i64>(
        |e| e.1,
        WindowAssigner::tumbling(100),
        WindowAgg::Count,
        lateness_ms,
    );
    let wins = wins.collect();
    let mut report = ctx.execute().unwrap();
    let mut got: Vec<(i64, i64)> = report.take(wins).unwrap();
    got.sort_unstable();
    let mut lates: Vec<(i64, (i64, i64))> = report.take(late).unwrap();
    lates.sort_unstable();
    let late_metric = report.metrics.late_records.load(Ordering::Relaxed);
    (got, lates, late_metric)
}

#[test]
fn prop_bounded_disorder_is_invisible_to_event_windows() {
    forall("ordered vs latency-shuffled window parity", 5, |g| {
        let n = 4_000i64;
        let step = 5i64;
        let keys = g.i64_in(2, 6);
        // delivery schedule: each event is delayed by a random latency in
        // [0, max_delay) ms, then the stream is replayed in arrival order
        // — the deterministic shape of a jittery network link
        let max_delay = g.i64_in(3, 8) * step;
        let ordered: Vec<(i64, i64)> = (0..n).map(|i| (i % keys, i * step)).collect();
        let mut arrival: Vec<(i64, (i64, i64))> = ordered
            .iter()
            .map(|&(k, ts)| (ts + g.i64_in(0, max_delay), (k, ts)))
            .collect();
        arrival.sort_by_key(|&(at, (_, ts))| (at, ts));
        let shuffled: Vec<(i64, i64)> = arrival.into_iter().map(|(_, e)| e).collect();
        assert_ne!(ordered, shuffled, "the schedule actually reordered something");

        let (base, base_late, base_metric) =
            run_windows(ordered, max_delay, 0, Duration::ZERO, false);
        let (got, got_late, got_metric) =
            run_windows(shuffled, max_delay, 0, Duration::from_millis(1), false);
        assert_eq!(
            base, got,
            "keys={keys} max_delay={max_delay}ms: disorder within the watermark \
             bound changed the window outputs"
        );
        let total: i64 = base.iter().map(|&(_, c)| c).sum();
        assert_eq!(total, n, "every record landed in exactly one pane");
        assert_eq!((base_metric, got_metric), (0, 0), "no record counted late");
        assert!(base_late.is_empty() && got_late.is_empty());
    });
}

#[test]
fn late_beyond_lateness_is_counted_and_captured_not_lost() {
    let keys = 4i64;
    let on_time = 3_000i64;
    let mut events: Vec<(i64, i64)> = (0..on_time).map(|i| (i % keys, i * 5)).collect();
    // stragglers: event times from the distant past, delivered last — far
    // beyond bound (40 ms) + lateness (100 ms) behind the watermark
    let stragglers = vec![(0i64, 0i64), (1, 120), (2, 250)];
    events.extend(stragglers.iter().copied());
    let total = events.len() as i64;

    let (wins, lates, late_metric) =
        run_windows(events, 40, 100, Duration::ZERO, true);
    assert_eq!(late_metric, stragglers.len() as u64, "each straggler counted once");
    let expected_lates: Vec<(i64, (i64, i64))> =
        stragglers.iter().map(|&(k, ts)| (k, (k, ts))).collect();
    assert_eq!(lates, expected_lates, "the side output captures the late records");
    // conservation: pane contents + late records account for every event
    let paned: i64 = wins.iter().map(|&(_, c)| c).sum();
    assert_eq!(
        paned + late_metric as i64,
        total,
        "no record was silently dropped"
    );
    assert_eq!(paned, on_time, "on-time records all fired in panes");
}
