//! Integration tests of the paper's §III "Dynamic updates": replacing a
//! FlowUnit's logic *by name* and adding a geographical location while the
//! rest of the deployment keeps running, with queue-decoupled boundaries.

use flowunits::api::raw::{JobConfig, PlannerKind, Replication, Source, StreamContext, WindowAgg};
use flowunits::config::{eval_cluster, fig2_cluster};
use flowunits::coordinator::Coordinator;
use flowunits::value::Value;
use std::time::Duration;

fn update_config() -> JobConfig {
    JobConfig {
        planner: PlannerKind::FlowUnits,
        decouple_units: true,
        batch_size: 64,
        poll_timeout: Duration::from_millis(10),
        ..Default::default()
    }
}

/// Builds `source@edge → filter@edge ∥ map(×10 + tag)@cloud → collect`
/// with a rate-limited source so the deployment stays alive for updates.
/// The `tag` (last decimal digit) identifies which model version scored
/// each event. Units are auto-named after their layers: "edge", "cloud".
fn updatable_graph(tag: i64, rate: f64, total: u64) -> flowunits::graph::LogicalGraph {
    let mut ctx = StreamContext::new(eval_cluster(None, Duration::ZERO), update_config());
    ctx.stream(Source::synthetic_rated(total, rate, |_, i| {
        Value::I64(i as i64)
    }))
    .to_layer("edge")
    .filter(|v| v.as_i64().unwrap() % 2 == 0)
    .to_layer("cloud")
    .map(move |v| Value::I64(v.as_i64().unwrap() * 10 + tag))
    .collect_vec();
    ctx.into_graph().unwrap()
}

#[test]
fn update_unit_by_name_swaps_logic_without_stopping_producers() {
    let cluster = eval_cluster(None, Duration::ZERO);
    let coord = Coordinator::new(cluster, update_config());
    let g1 = updatable_graph(1, 2_000.0, 1_000_000);
    let mut dep = coord.deploy(&g1).unwrap();
    assert_eq!(dep.unit_names(), vec!["edge", "cloud"]);

    std::thread::sleep(Duration::from_millis(300));
    let before_update = dep.metrics().events_in.load(std::sync::atomic::Ordering::Relaxed);
    assert!(before_update > 0, "sources are producing");

    // swap the cloud unit (by name) to tag 2 while edges keep producing
    let g2 = updatable_graph(2, 2_000.0, 1_000_000);
    dep.update_unit("cloud", g2).unwrap();

    std::thread::sleep(Duration::from_millis(300));
    let after_update = dep.metrics().events_in.load(std::sync::atomic::Ordering::Relaxed);
    assert!(
        after_update > before_update,
        "sources kept producing through the update ({before_update} -> {after_update})"
    );

    dep.stop_sources();
    let report = dep.wait().unwrap();
    // every filtered event was processed exactly once, by v1 xor v2 logic
    let (mut v1, mut v2, mut other) = (0u64, 0u64, 0u64);
    for v in &report.collected {
        match v.as_i64().unwrap() % 10 {
            1 => v1 += 1,
            2 => v2 += 1,
            _ => other += 1,
        }
    }
    assert_eq!(other, 0, "no unprocessed values leaked");
    assert!(v1 > 0, "old logic processed some events");
    assert!(v2 > 0, "new logic processed some events");
    // at-least-once across the swap; with drain-on-stop it is exactly-once
    assert_eq!(
        report.collected.len() as u64,
        report.events_in / 2,
        "every filtered event scored exactly once"
    );
}

/// The acceptance scenario for the first-class FlowUnit API: a job with
/// two sources, a `union`, a `split` into two sinks, and five named
/// FlowUnits; `update_unit("detector", …)` hot-swaps the middle unit
/// mid-run while sources and sinks keep going.
fn dag_graph(tag: i64) -> flowunits::graph::LogicalGraph {
    let mut ctx = StreamContext::new(eval_cluster(None, Duration::ZERO), update_config());
    let north = ctx
        .stream(Source::synthetic_rated(1_000_000, 2_000.0, |_, i| {
            Value::I64(i as i64)
        }))
        .unit("north")
        .to_layer("edge")
        .filter(|v| v.as_i64().unwrap() % 2 == 0);
    let south = ctx
        .stream(Source::synthetic_rated(1_000_000, 2_000.0, |_, i| {
            Value::I64(i as i64)
        }))
        .unit("south")
        .to_layer("edge");
    let scored = north
        .union(south)
        .unit("detector")
        .to_layer("cloud")
        .map(move |v| Value::I64(v.as_i64().unwrap() * 10 + tag));
    let (alerts, archive) = scored.split();
    alerts.unit("alerts").collect_vec();
    archive.unit("archive").collect_count();
    ctx.into_graph().unwrap()
}

#[test]
fn named_unit_hot_swap_in_multi_stream_dag() {
    let cluster = eval_cluster(None, Duration::ZERO);
    let coord = Coordinator::new(cluster, update_config());
    let mut dep = coord.deploy(&dag_graph(1)).unwrap();
    assert_eq!(
        dep.unit_names(),
        vec!["north", "south", "detector", "alerts", "archive"]
    );

    std::thread::sleep(Duration::from_millis(300));
    let before = dep.metrics().events_in.load(std::sync::atomic::Ordering::Relaxed);
    assert!(before > 0, "both sources are producing");

    // hot-swap the detector FlowUnit by name; everything else keeps running
    dep.update_unit("detector", dag_graph(2)).unwrap();

    std::thread::sleep(Duration::from_millis(300));
    let after = dep.metrics().events_in.load(std::sync::atomic::Ordering::Relaxed);
    assert!(
        after > before,
        "sources kept producing through the update ({before} -> {after})"
    );

    dep.stop_sources();
    let report = dep.wait().unwrap();
    let (mut v1, mut v2, mut other) = (0u64, 0u64, 0u64);
    for v in &report.collected {
        match v.as_i64().unwrap() % 10 {
            1 => v1 += 1,
            2 => v2 += 1,
            _ => other += 1,
        }
    }
    assert_eq!(other, 0, "no unscored values leaked to the alerts sink");
    assert!(v1 > 0, "detector v1 scored some events");
    assert!(v2 > 0, "detector v2 scored some events");
    assert!(!report.collected.is_empty());
}

/// Which stateful operator the hot-swapped unit holds.
#[derive(Clone, Copy)]
enum StatefulOp {
    ReduceSum,
    WindowCount(usize),
}

/// `source@edge → filter@edge ∥ "agg"@cloud: key_by → reduce/window →
/// collect`. The stateful stage is fed by a **direct internal hash
/// channel** from the key_by stage — exactly the shape `update_unit`
/// rejected before the epoch drain-and-handoff protocol.
fn stateful_graph(
    total: u64,
    rate: f64,
    keys: i64,
    op: StatefulOp,
    batch_size: usize,
    replication: Replication,
) -> flowunits::graph::LogicalGraph {
    let mut config = update_config();
    config.batch_size = batch_size;
    let mut ctx = StreamContext::new(eval_cluster(None, Duration::ZERO), config);
    let keyed = ctx
        .stream(Source::synthetic_rated(total, rate, |_, i| {
            Value::I64(i as i64)
        }))
        .to_layer("edge")
        .filter(|v| v.as_i64().unwrap() >= 0)
        .unit("agg")
        .to_layer("cloud")
        .replicate(replication)
        .key_by(move |v| Value::I64(v.as_i64().unwrap() % keys));
    match op {
        StatefulOp::ReduceSum => keyed
            .reduce(|a, b| Value::I64(a.as_i64().unwrap() + b.as_i64().unwrap()))
            .collect_vec(),
        StatefulOp::WindowCount(size) => keyed.window(size, WindowAgg::Count).collect_vec(),
    }
    ctx.into_graph().unwrap()
}

/// Runs `stateful_graph` to completion, optionally hot-swapping the
/// stateful unit after `swap_after`; returns the sorted collected output
/// and the final report.
fn run_stateful(
    total: u64,
    rate: f64,
    keys: i64,
    op: StatefulOp,
    batch_size: usize,
    swap_after: Option<Duration>,
    new_replication: Replication,
) -> (Vec<(i64, i64)>, flowunits::coordinator::JobReport) {
    let mut config = update_config();
    config.batch_size = batch_size;
    let coord = Coordinator::new(eval_cluster(None, Duration::ZERO), config);
    let g = stateful_graph(total, rate, keys, op, batch_size, Replication::PerCore);
    let mut dep = coord.deploy(&g).unwrap();
    if let Some(delay) = swap_after {
        std::thread::sleep(delay);
        dep.update_unit(
            "agg",
            stateful_graph(total, rate, keys, op, batch_size, new_replication),
        )
        .unwrap();
    }
    let report = dep.wait().unwrap();
    let mut got: Vec<(i64, i64)> = report
        .collected
        .iter()
        .map(|v| {
            let (k, x) = v.as_pair().unwrap();
            (k.as_i64().unwrap(), x.as_i64().unwrap())
        })
        .collect();
    got.sort_unstable();
    (got, report)
}

#[test]
fn stateful_unit_with_internal_channels_hot_swaps_exactly_once() {
    // previously rejected: "agg" holds a direct internal hash channel
    // (key_by stage → reduce stage) and keyed reduce state
    let total = 40_000;
    let (baseline, _) = run_stateful(
        total,
        10_000.0,
        16,
        StatefulOp::ReduceSum,
        64,
        None,
        Replication::PerCore,
    );
    let (swapped, report) = run_stateful(
        total,
        10_000.0,
        16,
        StatefulOp::ReduceSum,
        64,
        Some(Duration::from_millis(300)),
        Replication::PerCore,
    );
    assert_eq!(report.events_in, total, "every event was produced");
    assert_eq!(
        swapped, baseline,
        "zero lost, zero duplicated: per-key sums match the no-swap run exactly"
    );
    assert!(
        report
            .metrics
            .epochs_forwarded
            .load(std::sync::atomic::Ordering::Relaxed)
            > 0,
        "the swap drained the internal channels through epoch markers"
    );
    assert_eq!(report.corrupt_records, 0);
}

#[test]
fn placement_affecting_update_rolls_the_unit_and_keeps_results_exact() {
    // the swap changes the unit's replication (PerCore → PerHost): the
    // coordinator re-runs placement for the unit and rolls it, with the
    // handed-off state re-partitioned across the smaller instance set
    let total = 30_000;
    let (baseline, _) = run_stateful(
        total,
        10_000.0,
        8,
        StatefulOp::ReduceSum,
        64,
        None,
        Replication::PerCore,
    );
    let (swapped, report) = run_stateful(
        total,
        10_000.0,
        8,
        StatefulOp::ReduceSum,
        64,
        Some(Duration::from_millis(250)),
        Replication::PerHost,
    );
    assert_eq!(
        swapped, baseline,
        "per-key sums survive the placement change exactly"
    );
    assert_eq!(report.events_in, total);
}

#[test]
fn update_rejects_replication_change_on_other_units() {
    let coord = Coordinator::new(eval_cluster(None, Duration::ZERO), update_config());
    let g = stateful_graph(
        50_000,
        10_000.0,
        4,
        StatefulOp::ReduceSum,
        64,
        Replication::PerCore,
    );
    let mut dep = coord.deploy(&g).unwrap();
    // re-scope the *edge* unit while updating "agg": must be rejected
    let mut bad = stateful_graph(
        50_000,
        10_000.0,
        4,
        StatefulOp::ReduceSum,
        64,
        Replication::PerCore,
    );
    let edge_unit = bad.unit_named("edge").unwrap();
    bad.units[edge_unit].replication = Replication::PerZone;
    let err = dep.update_unit("agg", bad).unwrap_err();
    assert!(err.to_string().contains("only"), "got {err}");
    dep.stop_sources();
    dep.wait().unwrap();
}

#[test]
fn prop_hot_swap_under_load_loses_and_duplicates_nothing() {
    // property: for random key counts, batch sizes, swap timings, and
    // stateful operators, a hot swap under concurrent load produces
    // *exactly* the output of a no-swap run — zero loss, zero duplication
    flowunits::proptest::forall("hot swap is exactly-once", 3, |g| {
        let keys = g.i64_in(1, 24);
        let batch = [16, 64, 200][g.usize_in(0, 3)];
        let swap_ms = g.usize_in(50, 400) as u64;
        let op = if g.bool(0.5) {
            StatefulOp::ReduceSum
        } else {
            StatefulOp::WindowCount(g.usize_in(2, 50))
        };
        let total = 24_000;
        let (baseline, _) =
            run_stateful(total, 8_000.0, keys, op, batch, None, Replication::PerCore);
        let (swapped, report) = run_stateful(
            total,
            8_000.0,
            keys,
            op,
            batch,
            Some(Duration::from_millis(swap_ms)),
            Replication::PerCore,
        );
        assert_eq!(report.events_in, total);
        assert_eq!(
            swapped, baseline,
            "keys={keys} batch={batch} swap={swap_ms}ms: outputs diverged"
        );
    });
}

#[test]
fn update_rejects_non_decoupled_unit() {
    let cluster = eval_cluster(None, Duration::ZERO);
    let mut config = update_config();
    config.decouple_units = false;
    let coord = Coordinator::new(cluster, config);
    let g1 = updatable_graph(10, 10_000.0, 50_000);
    let mut dep = coord.deploy(&g1).unwrap();
    let err = dep.update_unit("cloud", updatable_graph(100, 10_000.0, 50_000));
    assert!(err.is_err());
    dep.stop_sources();
    dep.wait().unwrap();
}

#[test]
fn update_rejects_changed_structure() {
    let cluster = eval_cluster(None, Duration::ZERO);
    let coord = Coordinator::new(cluster.clone(), update_config());
    let g1 = updatable_graph(10, 10_000.0, 50_000);
    let mut dep = coord.deploy(&g1).unwrap();
    // structurally different graph (extra operator)
    let mut ctx = StreamContext::new(cluster, update_config());
    ctx.stream(Source::synthetic_rated(50_000, 10_000.0, |_, i| {
        Value::I64(i as i64)
    }))
    .to_layer("edge")
    .filter(|v| v.as_i64().unwrap() % 2 == 0)
    .to_layer("cloud")
    .map(|v| v)
    .map(|v| v)
    .collect_vec();
    let g2 = ctx.into_graph().unwrap();
    assert!(dep.update_unit("cloud", g2).is_err());
    dep.stop_sources();
    dep.wait().unwrap();
}

#[test]
fn update_rejects_unknown_unit_name() {
    let cluster = eval_cluster(None, Duration::ZERO);
    let coord = Coordinator::new(cluster, update_config());
    let g1 = updatable_graph(10, 10_000.0, 50_000);
    let mut dep = coord.deploy(&g1).unwrap();
    let err = dep
        .update_unit("no-such-unit", updatable_graph(11, 10_000.0, 50_000))
        .unwrap_err();
    assert!(err.to_string().contains("unknown FlowUnit"));
    // the index form remains available as a thin wrapper
    assert!(dep
        .update_unit_at(1, updatable_graph(12, 10_000.0, 50_000))
        .is_ok());
    dep.stop_sources();
    dep.wait().unwrap();
}

#[test]
fn add_location_extends_running_deployment() {
    // the paper's example: extend the computation to a new location whose
    // site zone is already active (L5 joins S2 alongside L4)
    let cluster = fig2_cluster();
    let mut config = update_config();
    config.locations = vec!["L1".into(), "L2".into(), "L4".into()];
    let coord = Coordinator::new(cluster, config);
    let g = {
        let mut ctx = StreamContext::new(fig2_cluster(), update_config());
        ctx.stream(Source::synthetic_rated(1_000_000, 2_000.0, |inst, i| {
            Value::pair(Value::I64(inst as i64), Value::I64(i as i64))
        }))
        .to_layer("edge")
        .map(|v| v)
        .to_layer("cloud")
        .collect_vec();
        ctx.into_graph().unwrap()
    };
    let mut dep = coord.deploy(&g).unwrap();
    std::thread::sleep(Duration::from_millis(250));

    // E5 (location L5) joins while the job runs
    dep.add_location("L5").unwrap();
    std::thread::sleep(Duration::from_millis(350));
    dep.stop_sources();
    let report = dep.wait().unwrap();

    // events from 4 distinct source instances exist: 3 original + E5's.
    // instance indices are per-plan: originals got 0..3, the added E5
    // instance reuses an index from the extended plan, so count distinct
    // (instance, first-event) pairs instead: all four edge zones produced.
    assert!(report.plan_description.contains("E5"), "plan extended to E5");
    assert!(report.events_in > 0);
    let distinct_sources: std::collections::BTreeSet<i64> = report
        .collected
        .iter()
        .map(|v| v.as_pair().unwrap().0.as_i64().unwrap())
        .collect();
    assert!(
        distinct_sources.len() >= 4,
        "expected events from ≥4 source instances, got {distinct_sources:?}"
    );
}

#[test]
fn add_location_rejects_duplicates_and_unknown() {
    let cluster = fig2_cluster();
    let mut config = update_config();
    config.locations = vec!["L1".into()];
    let coord = Coordinator::new(cluster, config);
    let g = updatable_graph_fig2();
    let mut dep = coord.deploy(&g).unwrap();
    assert!(dep.add_location("L1").is_err(), "duplicate location");
    assert!(dep.add_location("L99").is_err(), "unknown location");
    dep.stop_sources();
    dep.wait().unwrap();
}

fn updatable_graph_fig2() -> flowunits::graph::LogicalGraph {
    let mut ctx = StreamContext::new(fig2_cluster(), update_config());
    ctx.stream(Source::synthetic_rated(100_000, 5_000.0, |_, i| {
        Value::I64(i as i64)
    }))
    .to_layer("edge")
    .map(|v| v)
    .to_layer("cloud")
    .collect_count();
    ctx.into_graph().unwrap()
}
