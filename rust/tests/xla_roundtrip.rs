//! Integration: the AOT artifacts built by `make artifacts` load, compile,
//! and produce numerics matching the python model (within float tolerance).
//!
//! Requires `make artifacts` (skipped with a clear message otherwise).

use flowunits::runtime::xla_exec::XlaEngine;

fn engine_or_skip() -> Option<&'static XlaEngine> {
    if !std::path::Path::new("artifacts/double.hlo.txt").exists() {
        eprintln!("skipping: run `make artifacts` first");
        return None;
    }
    Some(XlaEngine::global().expect("PJRT CPU client"))
}

#[test]
fn double_artifact_roundtrip() {
    let Some(engine) = engine_or_skip() else { return };
    let art = engine.load("double").unwrap();
    let input: Vec<f32> = vec![0.0, 1.0, 2.0, 3.0, 4.0, 5.0];
    let out = art.execute_f32(&input, 2, 3).unwrap();
    assert_eq!(out, vec![0.0, 2.0, 4.0, 6.0, 8.0, 10.0]);
}

#[test]
fn anomaly_artifact_shapes_and_determinism() {
    let Some(engine) = engine_or_skip() else { return };
    let art = engine.load("anomaly_v1").unwrap();
    // 64 windows × 5 features, nominal values
    let mut rows = Vec::with_capacity(64 * 5);
    for i in 0..64 {
        let base = 50.0 + i as f32;
        rows.extend_from_slice(&[base, 3.0, base - 10.0, base + 10.0, base]);
    }
    let a = art.execute_f32(&rows, 64, 5).unwrap();
    let b = art.execute_f32(&rows, 64, 5).unwrap();
    assert_eq!(a.len(), 64); // out_dim 1
    assert_eq!(a, b, "deterministic inference");
    assert!(a.iter().all(|v| v.is_finite()));
}

#[test]
fn v1_and_v2_artifacts_disagree() {
    let Some(engine) = engine_or_skip() else { return };
    let v1 = engine.load("anomaly_v1").unwrap();
    let v2 = engine.load("anomaly_v2").unwrap();
    let rows: Vec<f32> = (0..64 * 5).map(|i| (i % 97) as f32).collect();
    let a = v1.execute_f32(&rows, 64, 5).unwrap();
    let b = v2.execute_f32(&rows, 64, 5).unwrap();
    assert_ne!(a, b, "v2 is a different trained model");
}

#[test]
fn nominal_features_score_at_output_bias() {
    // mirrors python/tests/test_kernel.py::test_zero_variance_features:
    // perfectly nominal features normalise to zero, so the score collapses
    // to the output bias (0.0 for v1).
    let Some(engine) = engine_or_skip() else { return };
    let art = engine.load("anomaly_v1").unwrap();
    let row = [50.0f32, 3.0, 40.0, 60.0, 50.0];
    let rows: Vec<f32> = row.iter().cycle().take(64 * 5).copied().collect();
    let out = art.execute_f32(&rows, 64, 5).unwrap();
    for v in out {
        assert!(v.abs() < 1e-4, "nominal score should be ~0, got {v}");
    }
}

#[test]
fn wrong_input_length_is_an_error() {
    let Some(engine) = engine_or_skip() else { return };
    let art = engine.load("double").unwrap();
    assert!(art.execute_f32(&[1.0, 2.0], 2, 3).is_err());
}

#[test]
fn artifact_cache_hits() {
    let Some(engine) = engine_or_skip() else { return };
    let a = engine.load("double").unwrap();
    let b = engine.load("double").unwrap();
    assert!(std::sync::Arc::ptr_eq(&a, &b));
    engine.evict("double");
    let c = engine.load("double").unwrap();
    assert!(!std::sync::Arc::ptr_eq(&a, &c));
}

#[test]
fn weights_survive_hlo_text_interchange() {
    // Regression: `as_hlo_text()` without `print_large_constants=True`
    // elides array constants as `constant({...})`, which the text parser
    // silently zeroes — every score collapses to the output bias. Distinct
    // non-nominal inputs must therefore yield distinct nonzero scores.
    let Some(engine) = engine_or_skip() else { return };
    let art = engine.load("anomaly_v1").unwrap();
    let mut rows = vec![
        50.3, 0.15, 50.0, 50.6, 50.4, // mildly off-nominal window
        93.0, 12.0, 50.0, 93.0, 93.0, // spiking window
    ];
    rows.resize(64 * 5, 0.0);
    let out = art.execute_f32(&rows, 64, 5).unwrap();
    assert!(
        (out[0] - 0.7783).abs() < 1e-3,
        "score[0] = {} — expected 0.7783 (python oracle); weights likely elided",
        out[0]
    );
    assert_ne!(out[0], out[1], "distinct windows must score differently");
}
