//! Integration tests for the typed API layer: `StreamData` round-trip
//! properties, typed end-to-end pipelines compared against their raw-API
//! equivalents under both planners, typed collect handles, and the
//! no-panic decode-failure paths. (The type-state guarantees — `window`
//! before `key_by`, cross-type `union` — are proven by the
//! `compile_fail` doc-tests in `api::typed`.)

use flowunits::api::raw;
use flowunits::config::eval_cluster;
use flowunits::prelude::*;
use flowunits::proptest::{forall, Gen};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::time::Duration;

fn cluster() -> ClusterSpec {
    eval_cluster(None, Duration::ZERO)
}

fn fast(planner: PlannerKind) -> JobConfig {
    JobConfig {
        planner,
        batch_size: 128,
        ..Default::default()
    }
}

// ---------------------------------------------------------------- values

fn gen_value(g: &mut Gen, depth: usize) -> Value {
    let arms = if depth == 0 { 5 } else { 8 };
    match g.usize_in(0, arms) {
        0 => Value::Null,
        1 => Value::Bool(g.bool(0.5)),
        2 => Value::I64(g.i64_in(-1_000_000, 1_000_000)),
        3 => Value::F64(g.f64_in(-1e9, 1e9)),
        4 => Value::Str(g.ident(12)),
        5 => {
            let a = gen_value(g, depth - 1);
            let b = gen_value(g, depth - 1);
            Value::pair(a, b)
        }
        6 => {
            let n = g.usize_in(0, 4);
            Value::List(g.vec_of(n, |g| gen_value(g, depth - 1)))
        }
        _ => {
            let n = g.usize_in(0, 4);
            Value::F32s(g.vec_of(n, |g| g.f64_in(-100.0, 100.0) as f32))
        }
    }
}

#[test]
fn stream_data_scalar_roundtrip_properties() {
    forall("i64 roundtrips", 256, |g| {
        let x = g.i64_in(i64::MIN / 2, i64::MAX / 2);
        assert_eq!(i64::try_from_value(x.into_value()).unwrap(), x);
    });
    forall("f64 roundtrips", 256, |g| {
        let x = g.f64_in(-1e12, 1e12);
        assert_eq!(f64::try_from_value(x.into_value()).unwrap(), x);
    });
    forall("bool roundtrips", 16, |g| {
        let x = g.bool(0.5);
        assert_eq!(bool::try_from_value(x.into_value()).unwrap(), x);
    });
    forall("String roundtrips", 256, |g| {
        let x = g.ident(24);
        assert_eq!(String::try_from_value(x.clone().into_value()).unwrap(), x);
    });
}

#[test]
fn stream_data_composite_roundtrip_properties() {
    forall("(i64, String) roundtrips", 128, |g| {
        let x = (g.i64_in(-1000, 1000), g.ident(8));
        assert_eq!(
            <(i64, String)>::try_from_value(x.clone().into_value()).unwrap(),
            x
        );
    });
    forall("nested tuple roundtrips", 128, |g| {
        let x = (
            (g.i64_in(-1000, 1000), g.f64_in(-10.0, 10.0)),
            (g.bool(0.5), g.ident(6)),
        );
        assert_eq!(
            <((i64, f64), (bool, String))>::try_from_value(x.clone().into_value()).unwrap(),
            x
        );
    });
    forall("3-tuple roundtrips", 128, |g| {
        let x = (g.i64_in(0, 100), g.f64_in(0.0, 1.0), g.bool(0.5));
        assert_eq!(
            <(i64, f64, bool)>::try_from_value(x.into_value()).unwrap(),
            x
        );
    });
    forall("Vec<i64> roundtrips", 128, |g| {
        let n = g.usize_in(0, 16);
        let x = g.vec_of(n, |g| g.i64_in(-1000, 1000));
        assert_eq!(<Vec<i64>>::try_from_value(x.clone().into_value()).unwrap(), x);
    });
    forall("Features roundtrips", 128, |g| {
        let n = g.usize_in(0, 8);
        let x = Features(g.vec_of(n, |g| g.f64_in(-100.0, 100.0) as f32));
        assert_eq!(Features::try_from_value(x.clone().into_value()).unwrap(), x);
    });
    forall("Value escape hatch roundtrips (incl. Null)", 256, |g| {
        let x = gen_value(g, 3);
        assert_eq!(Value::try_from_value(x.clone()).unwrap(), x);
    });
}

#[test]
fn stream_data_mismatches_are_decode_errors() {
    assert!(matches!(
        i64::try_from_value(Value::Str("7".into())),
        Err(Error::Decode(_))
    ));
    assert!(matches!(
        <(i64, i64)>::try_from_value(Value::List(vec![Value::I64(1), Value::I64(2)])),
        Err(Error::Decode(_)),
    ));
    assert!(matches!(
        <(i64, f64, bool)>::try_from_value(Value::List(vec![Value::I64(1)])),
        Err(Error::Decode(_)),
    ));
    assert!(matches!(
        Features::try_from_value(Value::List(vec![])),
        Err(Error::Decode(_))
    ));
}

// ------------------------------------------------- typed vs raw parity

fn typed_wordcount(planner: PlannerKind) -> Vec<(String, i64)> {
    let text = ["the cat", "the dog", "the cat sat"];
    let lines: Vec<String> = text.iter().map(|l| l.to_string()).collect();
    let mut ctx = StreamContext::new(cluster(), fast(planner));
    // zero `as_*()` / `unwrap()` calls inside the user closures below
    let handle = ctx
        .stream(Source::vector(lines))
        .to_layer("cloud")
        .flat_map(|line| {
            line.split(' ')
                .map(str::to_string)
                .collect::<Vec<String>>()
        })
        .group_by(|w| w.clone())
        .fold(0i64, |acc, _| *acc += 1)
        .collect();
    let mut report = ctx.execute().unwrap();
    let mut counts = report.take(handle).unwrap();
    counts.sort();
    counts
}

fn raw_wordcount(planner: PlannerKind) -> Vec<(String, i64)> {
    let text = ["the cat", "the dog", "the cat sat"];
    let lines: Vec<Value> = text.iter().map(|l| Value::Str(l.to_string())).collect();
    let mut ctx = StreamContext::new(cluster(), fast(planner));
    ctx.stream(raw::Source::vector(lines))
        .to_layer("cloud")
        .flat_map(|v| {
            v.as_str()
                .unwrap()
                .split(' ')
                .map(|w| Value::Str(w.to_string()))
                .collect()
        })
        .group_by(|w| w.clone())
        .fold(Value::I64(0), |acc, _| {
            *acc = Value::I64(acc.as_i64().unwrap() + 1)
        })
        .collect_vec();
    let report = ctx.execute().unwrap();
    let mut counts: Vec<(String, i64)> = report
        .collected
        .iter()
        .map(|v| {
            let (w, c) = v.as_pair().unwrap();
            (w.as_str().unwrap().to_string(), c.as_i64().unwrap())
        })
        .collect();
    counts.sort();
    counts
}

#[test]
fn typed_wordcount_matches_raw_under_both_planners() {
    for planner in [PlannerKind::FlowUnits, PlannerKind::Renoir] {
        let typed = typed_wordcount(planner);
        let raw = raw_wordcount(planner);
        assert_eq!(typed, raw, "{planner:?}");
        assert_eq!(
            typed,
            vec![
                ("cat".to_string(), 2),
                ("dog".to_string(), 1),
                ("sat".to_string(), 1),
                ("the".to_string(), 3)
            ],
            "{planner:?}"
        );
    }
}

fn typed_keyed_window(planner: PlannerKind) -> (u64, Vec<(i64, i64)>) {
    let mut ctx = StreamContext::new(cluster(), fast(planner));
    let handle = ctx
        .stream(Source::synthetic(8000, |_, i| i as i64))
        .to_layer("edge")
        .map(|v| v)
        .to_layer("site")
        .key_by(|v| v % 8)
        .window::<i64>(100, WindowAgg::Count)
        .to_layer("cloud")
        .collect();
    let mut report = ctx.execute().unwrap();
    let mut windows = report.take(handle).unwrap();
    windows.sort();
    (report.events_in, windows)
}

fn raw_keyed_window(planner: PlannerKind) -> (u64, Vec<(i64, i64)>) {
    let mut ctx = StreamContext::new(cluster(), fast(planner));
    ctx.stream(raw::Source::synthetic(8000, |_, i| Value::I64(i as i64)))
        .to_layer("edge")
        .map(|v| v)
        .to_layer("site")
        .key_by(|v| Value::I64(v.as_i64().unwrap() % 8))
        .window(100, WindowAgg::Count)
        .to_layer("cloud")
        .collect_vec();
    let report = ctx.execute().unwrap();
    let mut windows: Vec<(i64, i64)> = report
        .collected
        .iter()
        .map(|v| {
            let (k, c) = v.as_pair().unwrap();
            (k.as_i64().unwrap(), c.as_i64().unwrap())
        })
        .collect();
    windows.sort();
    (report.events_in, windows)
}

#[test]
fn typed_keyed_window_matches_raw_under_both_planners() {
    for planner in [PlannerKind::FlowUnits, PlannerKind::Renoir] {
        let (t_in, typed) = typed_keyed_window(planner);
        let (r_in, raw) = raw_keyed_window(planner);
        assert_eq!(t_in, r_in, "{planner:?}");
        assert_eq!(typed, raw, "{planner:?}");
        // 8000 events / 8 keys = 10 full windows per key, count=100 each
        assert_eq!(typed.len(), 80, "{planner:?}");
        assert!(typed.iter().all(|&(_, c)| c == 100), "{planner:?}");
    }
}

// ----------------------------------------------- typed-only pipelines

#[test]
fn typed_tuple_pipeline_reduces_keyed_max() {
    let mut ctx = StreamContext::new(cluster(), fast(PlannerKind::FlowUnits));
    let handle = ctx
        .stream(Source::synthetic(1000, |_, i| (i as i64 % 3, i as i64)))
        .to_layer("cloud")
        .key_by(|r| r.0)
        .map_values(|r| r.1)
        .reduce(|a, b| (*a).max(*b))
        .collect();
    let mut report = ctx.execute().unwrap();
    let mut maxes = report.take(handle).unwrap();
    maxes.sort();
    assert_eq!(maxes, vec![(0, 999), (1, 997), (2, 998)]);
}

#[test]
fn typed_union_inspect_and_count() {
    let seen = Arc::new(AtomicU64::new(0));
    let seen2 = seen.clone();
    let mut ctx = StreamContext::new(cluster(), fast(PlannerKind::FlowUnits));
    let north = ctx
        .stream(Source::synthetic(600, |_, i| i as i64))
        .unit("north")
        .to_layer("edge");
    let south = ctx
        .stream(Source::synthetic(400, |_, i| i as i64))
        .unit("south")
        .to_layer("edge");
    north
        .union(south)
        .unit("merge")
        .to_layer("cloud")
        .inspect(move |_| {
            seen2.fetch_add(1, Ordering::Relaxed);
        })
        .collect_count();
    let report = ctx.execute().unwrap();
    assert_eq!(report.events_out, 1000);
    assert_eq!(seen.load(Ordering::Relaxed), 1000);
}

#[test]
fn typed_features_window_feeds_typed_map_values() {
    let mut ctx = StreamContext::new(cluster(), fast(PlannerKind::FlowUnits));
    let handle = ctx
        .stream(Source::synthetic(64, |_, i| (0i64, i as f64)))
        .to_layer("cloud")
        .key_by(|r| r.0)
        .map_values(|r| r.1)
        .window::<Features>(32, WindowAgg::FeatureStats)
        .map_values(|Features(row)| row.len() as i64)
        .collect();
    let mut report = ctx.execute().unwrap();
    let rows = report.take(handle).unwrap();
    assert_eq!(rows, vec![(0, 5), (0, 5)], "two windows of 5 features each");
}

#[test]
fn keyed_entries_reinterpret_as_tuple_stream() {
    let mut ctx = StreamContext::new(cluster(), fast(PlannerKind::FlowUnits));
    let handle = ctx
        .stream(Source::synthetic(10, |_, i| i as i64))
        .to_layer("cloud")
        .key_by(|v| v % 2)
        .entries()
        .map(|(k, v)| k * 1000 + v)
        .collect();
    let mut report = ctx.execute().unwrap();
    let sum: i64 = report.take(handle).unwrap().into_iter().sum();
    // Σ (i % 2) * 1000 + i for i in 0..10 = 5000 + 45
    assert_eq!(sum, 5045);
}

#[test]
fn split_with_two_typed_sinks_segregates_by_handle() {
    let mut ctx = StreamContext::new(cluster(), fast(PlannerKind::FlowUnits));
    let s = ctx
        .stream(Source::synthetic(100, |_, i| i as i64))
        .to_layer("cloud");
    let (evens, labels) = s.split();
    let evens = evens.unit("evens").filter(|v| v % 2 == 0).collect();
    let labels = labels.unit("labels").map(|v| format!("v{v}")).collect();
    let mut report = ctx.execute().unwrap();
    let evens: Vec<i64> = report.take(evens).unwrap();
    let labels: Vec<String> = report.take(labels).unwrap();
    assert_eq!(evens.len(), 50);
    assert!(evens.iter().all(|v| v % 2 == 0));
    assert_eq!(labels.len(), 100);
    assert!(
        report.collected.is_empty(),
        "typed sinks do not leak into the flat collection"
    );
}

#[test]
fn take_of_an_empty_typed_sink_is_ok_and_empty() {
    let mut ctx = StreamContext::new(cluster(), fast(PlannerKind::FlowUnits));
    let handle = ctx
        .stream(Source::synthetic(100, |_, i| i as i64))
        .to_layer("cloud")
        .filter(|_| false)
        .collect();
    let mut report = ctx.execute().unwrap();
    let got: Vec<i64> = report.take(handle).unwrap();
    assert!(got.is_empty());
}

// --------------------------------------------------- no-panic failures

#[test]
fn mixed_raw_typed_decode_failure_is_error_not_panic() {
    let mut ctx = StreamContext::new(cluster(), fast(PlannerKind::FlowUnits));
    let untyped = ctx
        .stream(raw::Source::vector(vec![Value::Bool(true); 10]))
        .to_layer("cloud");
    // wrong claim: the stream carries Bool, not i64
    Stream::<i64>::from_raw(untyped).map(|v| v + 1).collect_count();
    let err = ctx.execute().unwrap_err();
    assert!(matches!(err, Error::Decode(_)), "got {err}");
    assert!(err.to_string().contains("i64"), "got {err}");
    assert_eq!(ctx.decode_failures(), 10, "every event counted");
}

#[test]
fn take_with_wrong_type_is_decode_error_not_panic() {
    let mut ctx = StreamContext::new(cluster(), fast(PlannerKind::FlowUnits));
    let untyped = ctx
        .stream(raw::Source::vector(vec![Value::Str("x".into())]))
        .to_layer("cloud");
    let handle = Stream::<i64>::from_raw(untyped).collect();
    // no typed closure ran, so the job itself succeeds ...
    let mut report = ctx.execute().unwrap();
    // ... and the mismatch surfaces at redemption time
    let err = report.take(handle).unwrap_err();
    assert!(matches!(err, Error::Decode(_)), "got {err}");
}

#[test]
fn handle_from_another_job_is_rejected_not_mixed_up() {
    let run = |n: u64| {
        let mut ctx = StreamContext::new(cluster(), fast(PlannerKind::FlowUnits));
        let handle = ctx
            .stream(Source::synthetic(n, |_, i| i as i64))
            .to_layer("cloud")
            .collect();
        (ctx.execute().unwrap(), handle)
    };
    let (mut report_a, handle_a) = run(10);
    let (mut report_b, handle_b) = run(20);
    // cross redemption: same sink op ids, different jobs — must error
    let err = report_a.take(handle_b).unwrap_err();
    assert!(matches!(err, Error::Decode(_)), "got {err}");
    assert!(
        err.to_string().contains("different builder context"),
        "got {err}"
    );
    // the opposite cross-redemption errors too ...
    assert!(report_b
        .take(handle_a)
        .unwrap_err()
        .to_string()
        .contains("different builder context"));
    // ... while a report's own handle redeems fine
    let (mut report_c, handle_c) = run(7);
    assert_eq!(report_c.take(handle_c).unwrap().len(), 7);
}

#[test]
fn decode_failures_suppress_events_instead_of_poisoning() {
    let mut ctx = StreamContext::new(cluster(), fast(PlannerKind::FlowUnits));
    let untyped = ctx
        .stream(raw::Source::vector(vec![
            Value::I64(1),
            Value::Bool(true), // the lie
            Value::I64(3),
        ]))
        .to_layer("cloud");
    let handle = Stream::<i64>::from_raw(untyped)
        .map(|v| v * 10)
        .filter(|v| *v > 0)
        .collect();
    let err = ctx.execute().unwrap_err();
    assert!(matches!(err, Error::Decode(_)), "got {err}");
    // exactly one failure: the bad event is dropped at the first shim and
    // never re-fails downstream
    assert_eq!(ctx.decode_failures(), 1);
    drop(handle);
}

#[test]
fn directory_as_file_source_is_job_error_not_silent_empty_stream() {
    let mut ctx = StreamContext::new(cluster(), fast(PlannerKind::FlowUnits));
    ctx.stream(Source::file_lines(std::env::temp_dir()))
        .to_layer("cloud")
        .collect_count();
    let err = ctx.execute().unwrap_err();
    assert!(err.to_string().contains("not a regular file"), "got {err}");
}

#[test]
fn unreadable_file_source_is_job_error_not_panic() {
    let mut ctx = StreamContext::new(cluster(), fast(PlannerKind::FlowUnits));
    ctx.stream(Source::file_lines("/definitely/not/here/fu.txt"))
        .to_layer("cloud")
        .collect_count();
    let err = ctx.execute().unwrap_err();
    assert!(matches!(err, Error::Runtime(_)), "got {err}");
    assert!(err.to_string().contains("cannot read file"), "got {err}");
}

#[test]
fn raw_unreadable_file_source_is_job_error_from_deploy_too() {
    let mut ctx = StreamContext::new(cluster(), fast(PlannerKind::FlowUnits));
    ctx.stream(raw::Source::file_lines("/definitely/not/here/fu.txt"))
        .to_layer("cloud")
        .collect_count();
    let err = ctx.deploy().err().expect("deploy must fail");
    assert!(err.to_string().contains("cannot read file"), "got {err}");
}

#[test]
fn typed_file_lines_wordcount_roundtrips_through_a_real_file() {
    let path = std::env::temp_dir().join(format!(
        "flowunits_typed_api_{}.txt",
        std::process::id()
    ));
    std::fs::write(&path, "alpha beta\nalpha\n").unwrap();
    let mut ctx = StreamContext::new(cluster(), fast(PlannerKind::FlowUnits));
    let handle = ctx
        .stream(Source::file_lines(&path))
        .to_layer("cloud")
        .flat_map(|line| {
            line.split_whitespace()
                .map(str::to_string)
                .collect::<Vec<String>>()
        })
        .group_by(|w| w.clone())
        .fold(0i64, |acc, _| *acc += 1)
        .collect();
    let mut report = ctx.execute().unwrap();
    std::fs::remove_file(&path).ok();
    let mut counts = report.take(handle).unwrap();
    counts.sort();
    assert_eq!(
        counts,
        vec![("alpha".to_string(), 2), ("beta".to_string(), 1)]
    );
}

#[test]
fn typed_to_layer_typo_is_builder_error() {
    let mut ctx = StreamContext::new(cluster(), fast(PlannerKind::FlowUnits));
    ctx.stream(Source::synthetic(10, |_, i| i as i64))
        .to_layer("clouds") // typo
        .collect_count();
    let err = ctx.execute().unwrap_err();
    assert!(matches!(err, Error::Graph(_)), "got {err}");
    assert!(err.to_string().contains("unknown layer"), "got {err}");
}
