"""L2 model + AOT artifact tests: shapes, padding, version divergence, and
HLO-text golden properties the rust loader depends on."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import aot
from compile.model import (
    BATCH,
    FEATURE_DIM,
    PARAMS_V1,
    PARAMS_V2,
    anomaly_v1,
    anomaly_v2,
    double,
    example_input,
)


def test_model_output_shape():
    x = example_input()
    (scores,) = anomaly_v1(x)
    assert scores.shape == (BATCH, 1)
    assert scores.dtype == jnp.float32


def test_model_pads_partial_batches():
    x = example_input(batch=10)
    (scores,) = anomaly_v1(x)
    assert scores.shape == (10, 1)
    # padding must not change real rows: compare against the full batch
    x64 = jnp.concatenate([x, jnp.zeros((BATCH - 10, FEATURE_DIM), jnp.float32)])
    (full,) = anomaly_v1(x64)
    np.testing.assert_allclose(scores, full[:10], rtol=1e-6)


def test_v1_and_v2_differ():
    x = example_input(seed=5)
    (s1,) = anomaly_v1(x)
    (s2,) = anomaly_v2(x)
    assert not np.allclose(np.asarray(s1), np.asarray(s2)), (
        "v2 must be a genuinely different model"
    )
    assert PARAMS_V1["w1"].shape == (FEATURE_DIM, 32)
    assert PARAMS_V2["w1"].shape == (FEATURE_DIM, 64)


def test_double_artifact_fn():
    x = jnp.arange(6.0, dtype=jnp.float32).reshape(2, 3)
    (y,) = double(x)
    np.testing.assert_array_equal(np.asarray(y), np.asarray(x) * 2)


@pytest.mark.parametrize("name", list(aot.ARTIFACTS))
def test_aot_lowering_produces_parseable_hlo_text(name):
    fn, specs = aot.ARTIFACTS[name]
    text = aot.lower_fn(fn, *specs)
    # properties the rust loader (HloModuleProto::from_text_file) relies on
    assert "ENTRY" in text
    assert "f32[" in text
    # tuple root: aot lowers with return_tuple=True
    assert "(f32[" in text
    assert len(text) > 200


def test_aot_scores_match_eager_model():
    """The lowered computation must equal the eager model numerically —
    executed through jax's own runtime here; the rust side re-checks the
    same artifact through PJRT in rust/tests/xla_roundtrip.rs."""
    x = example_input(seed=9)
    lowered = jax.jit(anomaly_v1).lower(
        jax.ShapeDtypeStruct((BATCH, FEATURE_DIM), jnp.float32)
    )
    compiled = lowered.compile()
    (got,) = compiled(x)
    (want,) = anomaly_v1(x)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-4, atol=1e-6)
