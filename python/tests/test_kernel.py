"""Kernel-vs-reference correctness: the CORE numeric signal of the stack.

The Pallas kernel (interpret mode) must match the pure-jnp oracle across
batch shapes, hidden widths, and input distributions; hypothesis drives the
sweep when available, with a deterministic fallback grid otherwise.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile.kernels.ref import window_scores_ref
from compile.kernels.window_stats import BLOCK_B, make_params, window_scores

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover
    HAVE_HYPOTHESIS = False


def random_batch(rng, b, d=5, scale=100.0):
    return jnp.asarray(rng.standard_normal((b, d)) * scale, jnp.float32)


@pytest.mark.parametrize("blocks", [1, 2, 4])
@pytest.mark.parametrize("hidden", [8, 32, 64])
def test_kernel_matches_ref_across_shapes(blocks, hidden):
    rng = np.random.default_rng(blocks * 100 + hidden)
    params = make_params(hidden=hidden, seed=3)
    x = random_batch(rng, blocks * BLOCK_B)
    got = window_scores(x, params)
    want = window_scores_ref(x, params)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


def test_kernel_rejects_non_multiple_batch():
    params = make_params()
    with pytest.raises(ValueError, match="not a multiple"):
        window_scores(jnp.zeros((BLOCK_B + 1, 5), jnp.float32), params)


def test_kernel_deterministic():
    params = make_params()
    x = random_batch(np.random.default_rng(0), BLOCK_B)
    a = window_scores(x, params)
    b = window_scores(x, params)
    np.testing.assert_array_equal(a, b)


def test_extreme_inputs_stay_finite():
    params = make_params()
    x = jnp.full((BLOCK_B, 5), 1e6, jnp.float32)
    got = window_scores(x, params)
    assert np.isfinite(np.asarray(got)).all()


def test_zero_variance_features():
    params = make_params()
    x = jnp.broadcast_to(
        jnp.array([50.0, 3.0, 40.0, 60.0, 50.0], jnp.float32), (BLOCK_B, 5)
    )
    got = window_scores(x, params)
    want = window_scores_ref(x, params)
    np.testing.assert_allclose(got, want, rtol=1e-6, atol=1e-6)
    # perfectly nominal features normalise to zeros → score = b2 path only
    np.testing.assert_allclose(got, np.full((BLOCK_B, 1), float(params["b2"][0])), atol=1e-5)


if HAVE_HYPOTHESIS:

    @settings(max_examples=40, deadline=None)
    @given(
        blocks=st.integers(min_value=1, max_value=3),
        hidden=st.sampled_from([4, 16, 32, 64]),
        seed=st.integers(min_value=0, max_value=2**31 - 1),
        scale=st.floats(min_value=0.01, max_value=1e4),
    )
    def test_kernel_matches_ref_hypothesis(blocks, hidden, seed, scale):
        rng = np.random.default_rng(seed)
        params = make_params(hidden=hidden, seed=seed % 1000)
        x = random_batch(rng, blocks * BLOCK_B, scale=scale)
        got = np.asarray(window_scores(x, params))
        want = np.asarray(window_scores_ref(x, params))
        np.testing.assert_allclose(got, want, rtol=2e-4, atol=2e-4)

else:  # deterministic fallback sweep

    @pytest.mark.parametrize("seed", range(12))
    def test_kernel_matches_ref_sweep(seed):
        rng = np.random.default_rng(seed)
        blocks = int(rng.integers(1, 4))
        hidden = int(rng.choice([4, 16, 32, 64]))
        scale = float(rng.uniform(0.01, 1e4))
        params = make_params(hidden=hidden, seed=seed)
        x = random_batch(rng, blocks * BLOCK_B, scale=scale)
        got = np.asarray(window_scores(x, params))
        want = np.asarray(window_scores_ref(x, params))
        np.testing.assert_allclose(got, want, rtol=2e-4, atol=2e-4)


def test_feature_stats_ref_matches_rust_convention():
    # mirrors rust/src/runtime/exec.rs WindowAgg::FeatureStats semantics
    from compile.kernels.ref import feature_stats_ref

    w = [1.0, 3.0]
    got = np.asarray(feature_stats_ref(w))
    np.testing.assert_allclose(got, [2.0, 1.0, 1.0, 3.0, 3.0], atol=1e-7)
