"""AOT lowering: JAX → HLO *text* artifacts for the rust PJRT runtime.

HLO text (not ``.serialize()``) is the interchange format: jax ≥ 0.5 emits
``HloModuleProto``s with 64-bit instruction ids that the pinned
xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``); the text parser
reassigns ids and round-trips cleanly. See ``/opt/xla-example/README.md``.

Usage::

    cd python && python -m compile.aot --out-dir ../artifacts

Artifacts produced:
  * ``anomaly_v1.hlo.txt`` — f32[64,5] → f32[64,1] window anomaly scores
  * ``anomaly_v2.hlo.txt`` — the 'retrained' variant (dynamic-update demo)
  * ``double.hlo.txt``     — f32[2,3] → f32[2,3] runtime smoke artifact
"""

from __future__ import annotations

import argparse
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from .model import BATCH, FEATURE_DIM, anomaly_v1, anomaly_v2, double


def to_hlo_text(lowered) -> str:
    """Converts a jax lowering to XLA HLO text with a tuple root.

    ``print_large_constants=True`` is load-bearing: the default printer
    elides big array constants as ``constant({...})``, which the HLO text
    parser silently turns into **zeros** — the model's baked-in weights
    would vanish. (Caught by rust/tests/xla_roundtrip.rs numerics checks.)
    """
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    text = comp.as_hlo_text(print_large_constants=True)
    assert "{...}" not in text, "elided constants survived — artifact would be corrupt"
    return text


def lower_fn(fn, *specs) -> str:
    return to_hlo_text(jax.jit(fn).lower(*specs))


ARTIFACTS = {
    "anomaly_v1": (
        anomaly_v1,
        (jax.ShapeDtypeStruct((BATCH, FEATURE_DIM), jnp.float32),),
    ),
    "anomaly_v2": (
        anomaly_v2,
        (jax.ShapeDtypeStruct((BATCH, FEATURE_DIM), jnp.float32),),
    ),
    "double": (double, (jax.ShapeDtypeStruct((2, 3), jnp.float32),)),
}


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--only", help="build a single artifact by name")
    args = ap.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)
    for name, (fn, specs) in ARTIFACTS.items():
        if args.only and name != args.only:
            continue
        text = lower_fn(fn, *specs)
        path = os.path.join(args.out_dir, f"{name}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        print(f"wrote {name}: {len(text)} chars -> {path}")


if __name__ == "__main__":
    main()
