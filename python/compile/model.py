"""L2 — the JAX model: batched anomaly scoring over window features.

The model wraps the L1 Pallas kernel (``kernels/window_stats.py``) with
batch padding so the compiled artifact accepts exactly the fixed batch the
rust runtime feeds it. Two 'trained' versions exist:

* ``anomaly_v1`` — hidden width 32, neutral output bias (initial model);
* ``anomaly_v2`` — hidden width 64, shifted bias (the 'retrained' model the
  dynamic-update demo swaps in without stopping other FlowUnits).

Both are lowered once at build time by ``aot.py``; Python never runs on
the request path.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .kernels.window_stats import BLOCK_B, make_params, window_scores

#: feature dimension: [mean, std, min, max, last]
FEATURE_DIM = 5
#: compiled inference batch (rows per PJRT call from the rust hot path)
BATCH = 64

PARAMS_V1 = make_params(hidden=32, seed=7, bias_shift=0.0)
PARAMS_V2 = make_params(hidden=64, seed=11, bias_shift=-0.25)


def _pad_to_block(x):
    """Pads the batch dimension up to a BLOCK_B multiple for the kernel."""
    b = x.shape[0]
    padded = ((b + BLOCK_B - 1) // BLOCK_B) * BLOCK_B
    if padded != b:
        x = jnp.pad(x, ((0, padded - b), (0, 0)))
    return x, b


def anomaly_model(params):
    """Returns the jit-able scoring function for one parameter set."""

    def fwd(x):
        xp, b = _pad_to_block(x)
        scores = window_scores(xp, params)
        return (scores[:b],)  # 1-tuple: the AOT path lowers return_tuple=True

    return fwd


anomaly_v1 = anomaly_model(PARAMS_V1)
anomaly_v2 = anomaly_model(PARAMS_V2)


def double(x):
    """Trivial artifact used by the rust runtime integration tests."""
    return (x * 2.0,)


def example_input(batch: int = BATCH, seed: int = 0):
    """A plausible feature batch for lowering/testing."""
    k = jax.random.PRNGKey(seed)
    base = jax.random.normal(k, (batch, FEATURE_DIM), jnp.float32)
    return base * jnp.array([20.0, 2.0, 20.0, 20.0, 20.0]) + jnp.array(
        [50.0, 3.0, 40.0, 60.0, 50.0]
    )
