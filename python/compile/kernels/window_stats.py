"""L1 — Pallas kernel: fused window-feature normalisation + MLP anomaly
scorer.

This is the compute hot-spot of the paper's running example (Fig. 1): the
cloud-layer ML step that scores windowed sensor features. The rust runtime
feeds batches of ``[B, D]`` feature rows (``[mean, std, min, max, last]``
per window, D = 5); the kernel normalises them and applies a two-layer MLP
in a single fused pass:

    y = relu((x - mu) / sigma @ W1 + b1) @ W2 + b2          # [B, 1]

TPU adaptation (DESIGN.md §Hardware-Adaptation): the batch dimension is
tiled into VMEM-resident blocks of ``BLOCK_B`` rows via ``BlockSpec``; the
(tiny) weight matrices are replicated into VMEM for every grid step; the
two matmuls target the MXU. ``interpret=True`` everywhere — the CPU PJRT
plugin cannot execute Mosaic custom-calls, and correctness is validated
against the pure-jnp oracle in ``ref.py``.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Rows per VMEM block. At D=5, H<=64 this keeps the working set
# (x block + both weights + activations) well under 1 MiB of VMEM:
#   128*5*4 + 5*64*4 + 64*4 + 128*64*4 + 64*1*4 + 128*1*4 ≈ 37 KiB.
BLOCK_B = 128


def _kernel(x_ref, mu_ref, sigma_ref, w1_ref, b1_ref, w2_ref, b2_ref, o_ref):
    """One grid step: score a [BLOCK_B, D] tile of feature rows."""
    x = x_ref[...]
    # feature normalisation (vectorised on the VPU)
    z = (x - mu_ref[...]) / sigma_ref[...]
    # MXU matmul 1 + bias + relu
    h = jnp.maximum(jnp.dot(z, w1_ref[...]) + b1_ref[...], 0.0)
    # MXU matmul 2 + bias
    o_ref[...] = jnp.dot(h, w2_ref[...]) + b2_ref[...]


@functools.partial(jax.jit, static_argnames=("block_b",))
def window_scores(x, params, block_b: int = BLOCK_B):
    """Scores a batch of window-feature rows.

    Args:
      x: ``f32[B, D]`` feature rows; B must be a multiple of ``block_b``
        (the AOT wrapper pads).
      params: dict with ``mu``/``sigma`` (``f32[D]``), ``w1`` (``f32[D,H]``),
        ``b1`` (``f32[H]``), ``w2`` (``f32[H,1]``), ``b2`` (``f32[1]``).
      block_b: rows per VMEM block.

    Returns:
      ``f32[B, 1]`` anomaly scores.
    """
    b, d = x.shape
    h = params["w1"].shape[1]
    if b % block_b != 0:
        raise ValueError(f"batch {b} not a multiple of block_b {block_b}")
    grid = (b // block_b,)
    full = lambda *s: pl.BlockSpec(s, lambda i: tuple(0 for _ in s))  # noqa: E731
    return pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_b, d), lambda i: (i, 0)),  # x: tiled over batch
            full(d),  # mu: replicated
            full(d),  # sigma
            full(d, h),  # w1
            full(h),  # b1
            full(h, 1),  # w2
            full(1),  # b2
        ],
        out_specs=pl.BlockSpec((block_b, 1), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((b, 1), x.dtype),
        interpret=True,  # CPU path; real TPU would lower to Mosaic
    )(x, params["mu"], params["sigma"], params["w1"], params["b1"], params["w2"], params["b2"])


def make_params(hidden: int = 32, seed: int = 7, bias_shift: float = 0.0):
    """Deterministic model parameters (the 'trained' weights baked into an
    artifact version). ``bias_shift`` recalibrates the output bias — the v2
    'retrained' model uses a wider hidden layer and a shifted threshold."""
    k = jax.random.PRNGKey(seed)
    k1, k2 = jax.random.split(k)
    d = 5
    return {
        "mu": jnp.array([50.0, 3.0, 40.0, 60.0, 50.0], jnp.float32),
        "sigma": jnp.array([20.0, 2.0, 20.0, 20.0, 20.0], jnp.float32),
        "w1": jax.random.normal(k1, (d, hidden), jnp.float32) / jnp.sqrt(d),
        "b1": jnp.zeros((hidden,), jnp.float32),
        "w2": jax.random.normal(k2, (hidden, 1), jnp.float32) / jnp.sqrt(hidden),
        "b2": jnp.full((1,), bias_shift, jnp.float32),
    }
