"""Pure-jnp oracle for the Pallas kernel — the CORE correctness reference.

Every behaviour of ``window_stats.window_scores`` must match this
implementation to float tolerance; pytest sweeps shapes and inputs against
it (``python/tests/test_kernel.py``).
"""

from __future__ import annotations

import jax.numpy as jnp


def window_scores_ref(x, params):
    """Reference scorer: identical math, no Pallas, no tiling."""
    z = (x - params["mu"]) / params["sigma"]
    h = jnp.maximum(z @ params["w1"] + params["b1"], 0.0)
    return h @ params["w2"] + params["b2"]


def feature_stats_ref(window):
    """Reference for the rust-side ``WindowAgg::FeatureStats`` aggregate:
    ``[mean, std, min, max, last]`` of a 1-D window (population std)."""
    w = jnp.asarray(window, jnp.float32)
    return jnp.stack(
        [w.mean(), w.std(), w.min(), w.max(), w[-1]]
    )
