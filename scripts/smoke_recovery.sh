#!/usr/bin/env bash
# Chaos smoke test: one coordinator + two worker processes run the paced
# wordcount over Unix domain sockets, and one worker is SIGKILLed while
# the job is in flight. The daemon must declare the worker dead (socket
# EOF), redispatch the job over the survivor, and finish; the collected
# output must still be byte-identical to the in-process engine's run.
# Run from the repo root after `cargo build --release`.
#
#   FLOWUNITS_BIN     path to the flowunits binary (default target/release/flowunits)
#   SMOKE_EVENTS      events to stream (default 600000 — paced at 20k ev/s
#                     per source, so the job outlives the kill below)
#   SMOKE_KILL_AFTER  seconds to wait before the SIGKILL (default 1)
set -euo pipefail

BIN="${FLOWUNITS_BIN:-target/release/flowunits}"
EVENTS="${SMOKE_EVENTS:-600000}"
KILL_AFTER="${SMOKE_KILL_AFTER:-1}"
if [ ! -x "$BIN" ]; then
  echo "smoke: binary '$BIN' not found — run 'cargo build --release' first" >&2
  exit 1
fi
DIR="$(mktemp -d)"
trap 'kill $(jobs -p) 2>/dev/null || true; rm -rf "$DIR"' EXIT
SOCK="$DIR/coordinator.sock"

"$BIN" coordinator --listen "$SOCK" --workers 2 --pipeline wordcount_paced \
  --events "$EVENTS" --timeout-s 120 --show-collected >"$DIR/dist.out" 2>&1 &
COORD=$!
"$BIN" worker --connect "$SOCK" --id w1 --state-dir "$DIR/w1" >"$DIR/w1.log" 2>&1 &
"$BIN" worker --connect "$SOCK" --id w2 --state-dir "$DIR/w2" >"$DIR/w2.log" 2>&1 &
VICTIM=$!

sleep "$KILL_AFTER"
if ! kill -9 "$VICTIM" 2>/dev/null; then
  echo "smoke: FAIL — worker w2 was already gone before the injected kill" >&2
  exit 1
fi

if ! wait "$COORD"; then
  echo "smoke: FAIL — coordinator did not survive the worker kill —" >&2
  cat "$DIR/dist.out" >&2
  exit 1
fi
# the successful attempt must have run on the lone survivor
if ! grep -q '^distributed job: 1 worker(s)' "$DIR/dist.out"; then
  echo "smoke: FAIL — expected a redispatch over 1 surviving worker —" >&2
  cat "$DIR/dist.out" >&2
  exit 1
fi
grep '^collected: ' "$DIR/dist.out" | sort >"$DIR/dist.collected"

"$BIN" run --pipeline wordcount_paced --events "$EVENTS" --show-collected >"$DIR/local.out"
grep '^collected: ' "$DIR/local.out" | sort >"$DIR/local.collected"

if ! diff -u "$DIR/local.collected" "$DIR/dist.collected"; then
  echo "smoke: FAIL — post-recovery output differs from the in-process run" >&2
  exit 1
fi
echo "smoke: OK — worker killed mid-job, coordinator redispatched, output matches in-process" \
     "($(wc -l <"$DIR/dist.collected") collected lines)"
