#!/usr/bin/env bash
# Chaos smoke tests: one coordinator + two worker processes run the paced
# wordcount over Unix domain sockets while a process is SIGKILLed mid-job.
#
# Scenario 1 — worker death: one worker is killed while the job is in
# flight. The daemon must declare it dead (socket EOF), redispatch the
# job over the survivor, and finish; the collected output must still be
# byte-identical to the in-process engine's run.
#
# Scenario 2 — coordinator death: the coordinator itself is SIGKILLed
# mid-job. The dispatch left a job manifest in --data-dir; a restarted
# coordinator on the same socket must find it, re-adopt the reconnecting
# workers, re-run the interrupted job, and produce identical output.
#
# Run from the repo root after `cargo build --release`.
#
#   FLOWUNITS_BIN     path to the flowunits binary (default target/release/flowunits)
#   SMOKE_EVENTS      events to stream (default 600000 — paced at 20k ev/s
#                     per source, so the job outlives the kills below)
#   SMOKE_KILL_AFTER  seconds to wait before each SIGKILL (default 1)
set -euo pipefail

BIN="${FLOWUNITS_BIN:-target/release/flowunits}"
EVENTS="${SMOKE_EVENTS:-600000}"
KILL_AFTER="${SMOKE_KILL_AFTER:-1}"
if [ ! -x "$BIN" ]; then
  echo "smoke: binary '$BIN' not found — run 'cargo build --release' first" >&2
  exit 1
fi
DIR="$(mktemp -d)"
trap 'kill $(jobs -p) 2>/dev/null || true; rm -rf "$DIR"' EXIT

# the one in-process reference run both scenarios diff against
"$BIN" run --pipeline wordcount_paced --events "$EVENTS" --show-collected >"$DIR/local.out"
grep '^collected: ' "$DIR/local.out" | sort >"$DIR/local.collected"

# --- scenario 1: SIGKILL a worker mid-job ---------------------------------
SOCK="$DIR/coordinator.sock"
"$BIN" coordinator --listen "$SOCK" --workers 2 --pipeline wordcount_paced \
  --events "$EVENTS" --timeout-s 120 --show-collected >"$DIR/dist.out" 2>&1 &
COORD=$!
"$BIN" worker --connect "$SOCK" --id w1 --state-dir "$DIR/w1" >"$DIR/w1.log" 2>&1 &
W1=$!
"$BIN" worker --connect "$SOCK" --id w2 --state-dir "$DIR/w2" >"$DIR/w2.log" 2>&1 &
VICTIM=$!

sleep "$KILL_AFTER"
if ! kill -9 "$VICTIM" 2>/dev/null; then
  echo "smoke: FAIL — worker w2 was already gone before the injected kill" >&2
  exit 1
fi

if ! wait "$COORD"; then
  echo "smoke: FAIL — coordinator did not survive the worker kill —" >&2
  cat "$DIR/dist.out" >&2
  exit 1
fi
# the successful attempt must have run on the lone survivor
if ! grep -q '^distributed job: 1 worker(s)' "$DIR/dist.out"; then
  echo "smoke: FAIL — expected a redispatch over 1 surviving worker —" >&2
  cat "$DIR/dist.out" >&2
  exit 1
fi
grep '^collected: ' "$DIR/dist.out" | sort >"$DIR/dist.collected"

if ! diff -u "$DIR/local.collected" "$DIR/dist.collected"; then
  echo "smoke: FAIL — post-recovery output differs from the in-process run" >&2
  exit 1
fi
kill "$W1" 2>/dev/null || true
wait "$W1" 2>/dev/null || true
echo "smoke: OK — worker killed mid-job, coordinator redispatched, output matches in-process" \
     "($(wc -l <"$DIR/dist.collected") collected lines)"

# --- scenario 2: SIGKILL the coordinator mid-job --------------------------
SOCK2="$DIR/coordinator2.sock"
DATA="$DIR/coord-data"
"$BIN" coordinator --listen "$SOCK2" --workers 2 --pipeline wordcount_paced \
  --events "$EVENTS" --timeout-s 120 --data-dir "$DATA" \
  --show-collected >"$DIR/coord1.out" 2>&1 &
COORD1=$!
"$BIN" worker --connect "$SOCK2" --id v1 --state-dir "$DIR/v1" >"$DIR/v1.log" 2>&1 &
V1=$!
"$BIN" worker --connect "$SOCK2" --id v2 --state-dir "$DIR/v2" >"$DIR/v2.log" 2>&1 &
V2=$!

# wait until the job is actually dispatched (the manifest appears), then
# give it a moment in flight before the kill
DEADLINE=$((SECONDS + 30))
while [ ! -f "$DATA/job.manifest" ]; do
  if [ "$SECONDS" -ge "$DEADLINE" ]; then
    echo "smoke: FAIL — coordinator never persisted a job manifest —" >&2
    cat "$DIR/coord1.out" >&2
    exit 1
  fi
  sleep 0.1
done
sleep "$KILL_AFTER"
if ! kill -9 "$COORD1" 2>/dev/null; then
  echo "smoke: FAIL — coordinator finished before the injected kill" >&2
  exit 1
fi
wait "$COORD1" 2>/dev/null || true

if [ ! -f "$DATA/job.manifest" ]; then
  echo "smoke: FAIL — killed coordinator left no job manifest behind" >&2
  exit 1
fi

# successor on the same socket + data dir: resumes the manifested job over
# the re-registering workers
if ! "$BIN" coordinator --listen "$SOCK2" --workers 2 --pipeline wordcount_paced \
    --events "$EVENTS" --timeout-s 120 --data-dir "$DATA" \
    --show-collected >"$DIR/coord2.out" 2>&1; then
  echo "smoke: FAIL — restarted coordinator did not finish the job —" >&2
  cat "$DIR/coord2.out" >&2
  exit 1
fi
if ! grep -q '^resuming interrupted job' "$DIR/coord2.out"; then
  echo "smoke: FAIL — restarted coordinator did not announce the resume —" >&2
  cat "$DIR/coord2.out" >&2
  exit 1
fi
if [ -f "$DATA/job.manifest" ]; then
  echo "smoke: FAIL — completed resume left the job manifest behind" >&2
  exit 1
fi
grep '^collected: ' "$DIR/coord2.out" | sort >"$DIR/resume.collected"
if ! diff -u "$DIR/local.collected" "$DIR/resume.collected"; then
  echo "smoke: FAIL — post-restart output differs from the in-process run" >&2
  exit 1
fi
kill "$V1" "$V2" 2>/dev/null || true
wait "$V1" "$V2" 2>/dev/null || true
echo "smoke: OK — coordinator killed mid-job, successor resumed from the manifest, output matches" \
     "($(wc -l <"$DIR/resume.collected") collected lines)"
