#!/usr/bin/env python3
"""Smoke-mode perf regression gate.

Compares a freshly produced bench JSON against the checked-in baseline
floors and fails if any shared scenario's throughput dropped more than 2x
below its floor. The baseline records deliberately conservative floors
(see BENCH_baseline.json) so the gate catches disasters — an accidental
debug sleep, an O(n^2) hot loop — without flaking on runner noise; ratchet
the floors upward as the trajectory improves.

The baseline may hold one section per bench binary under "benches",
keyed by the measured JSON's "bench" field (scenario names like "linear"
recur across benches, so floors are scoped); a baseline with a top-level
"scenarios" list is the legacy single-bench layout and is used as-is.

Usage: bench_gate.py <measured.json> <baseline.json>
Set BENCH_GATE_SKIP=1 to bypass (e.g. when bisecting an unrelated break).
"""

import json
import os
import sys


def scenarios(doc):
    return {s["name"]: s for s in doc.get("scenarios", [])}


def load_doc(path, role):
    """Loads one bench JSON, exiting with a clear message (not a
    traceback) when the file is missing or malformed — the usual causes
    are a bench binary that crashed before writing its output, or a stale
    path in the CI recipe."""
    try:
        with open(path) as f:
            doc = json.load(f)
    except FileNotFoundError:
        sys.exit(
            f"bench gate: {role} file '{path}' does not exist "
            "(did the bench run fail before writing its JSON?)"
        )
    except json.JSONDecodeError as e:
        sys.exit(f"bench gate: {role} file '{path}' is not valid JSON: {e}")
    if not isinstance(doc, dict):
        sys.exit(f"bench gate: {role} file '{path}' is not a JSON object")
    return doc


def section_scenarios(doc, path, role):
    """Scenario table of one bench document (measured files and the
    legacy flat baseline layout)."""
    if not isinstance(doc.get("scenarios"), list):
        sys.exit(
            f"bench gate: {role} file '{path}' has no 'scenarios' list "
            "(expected the layout written by the bench binaries)"
        )
    try:
        return scenarios(doc)
    except (KeyError, TypeError) as e:
        sys.exit(f"bench gate: {role} file '{path}' has a malformed scenario entry: {e}")


def baseline_scenarios(doc, path, bench_name):
    """Picks the floor table for `bench_name`: the matching "benches"
    section when present, else the whole document (legacy layout)."""
    benches = doc.get("benches")
    if isinstance(benches, dict):
        section = benches.get(bench_name)
        if not isinstance(section, dict):
            sys.exit(
                f"bench gate: baseline '{path}' has no section for bench "
                f"'{bench_name}' (known: {', '.join(sorted(benches))})"
            )
        return section_scenarios(section, path, "baseline")
    return section_scenarios(doc, path, "baseline")


def main():
    if os.environ.get("BENCH_GATE_SKIP") == "1":
        print("bench gate: skipped (BENCH_GATE_SKIP=1)")
        return 0
    if len(sys.argv) != 3:
        sys.exit("usage: bench_gate.py <measured.json> <baseline.json>")
    measured_doc = load_doc(sys.argv[1], "measured")
    measured = section_scenarios(measured_doc, sys.argv[1], "measured")
    bench_name = measured_doc.get("bench", "")
    baseline = baseline_scenarios(load_doc(sys.argv[2], "baseline"), sys.argv[2], bench_name)
    print(f"bench gate: '{bench_name or sys.argv[1]}' vs baseline floors")
    failures = []
    for name, base in sorted(baseline.items()):
        floor = base.get("throughput_ev_s")
        got = measured.get(name, {}).get("throughput_ev_s")
        if floor is None or got is None:
            print(f"  {name:<12} (no shared throughput figure; skipped)")
            continue
        threshold = floor / 2.0
        verdict = "ok" if got >= threshold else "FAIL"
        print(
            f"  {name:<12} measured {got:>12.1f} ev/s   "
            f"floor {floor:>10.1f}   gate {threshold:>10.1f}   {verdict}"
        )
        if got < threshold:
            failures.append(name)
    if failures:
        print(f"bench gate: FAILED for {', '.join(failures)}")
        return 1
    print("bench gate: ok")
    return 0


if __name__ == "__main__":
    sys.exit(main())
