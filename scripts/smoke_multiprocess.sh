#!/usr/bin/env bash
# Multi-process smoke test: one coordinator + two worker processes over
# Unix domain sockets run a keyed wordcount end-to-end; the collected
# output must be byte-identical to the single-process engine's run of the
# same pipeline. Run from the repo root after `cargo build --release`.
#
#   FLOWUNITS_BIN  path to the flowunits binary (default target/release/flowunits)
#   SMOKE_EVENTS   events to stream (default 6000)
set -euo pipefail

BIN="${FLOWUNITS_BIN:-target/release/flowunits}"
EVENTS="${SMOKE_EVENTS:-6000}"
if [ ! -x "$BIN" ]; then
  echo "smoke: binary '$BIN' not found — run 'cargo build --release' first" >&2
  exit 1
fi
DIR="$(mktemp -d)"
trap 'kill $(jobs -p) 2>/dev/null || true; rm -rf "$DIR"' EXIT
SOCK="$DIR/coordinator.sock"

"$BIN" coordinator --listen "$SOCK" --workers 2 --pipeline wordcount \
  --events "$EVENTS" --timeout-s 120 --show-collected >"$DIR/dist.out" 2>&1 &
COORD=$!
for i in 1 2; do
  "$BIN" worker --connect "$SOCK" --id "w$i" --state-dir "$DIR/w$i" \
    >"$DIR/w$i.log" 2>&1 &
done

if ! wait "$COORD"; then
  echo "smoke: coordinator failed —" >&2
  cat "$DIR/dist.out" >&2
  exit 1
fi
grep '^collected: ' "$DIR/dist.out" | sort >"$DIR/dist.collected"

"$BIN" run --pipeline wordcount --events "$EVENTS" --show-collected >"$DIR/local.out"
grep '^collected: ' "$DIR/local.out" | sort >"$DIR/local.collected"

if ! diff -u "$DIR/local.collected" "$DIR/dist.collected"; then
  echo "smoke: FAIL — distributed output differs from the in-process run" >&2
  exit 1
fi
echo "smoke: OK — distributed wordcount matches in-process ($(wc -l <"$DIR/dist.collected") collected lines)"
